"""Multi-device tests (subprocess with forced host device counts):
sharding specs, distributed graph engine, compressed all-reduce,
sharded train step, and a small dry-run cell."""
import numpy as np
import pytest

from repro.distributed import sharding as shardlib


# --------------------------------------------------------------------------
# pure spec logic (no devices needed)
# --------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_spec_for_divisibility_fallback():
    mesh = _FakeMesh({"data": 4, "model": 8})
    s = shardlib.spec_for(mesh, (16, 24), ("embed", "mlp"))
    assert s == __import__("jax").sharding.PartitionSpec("data", "model")
    s2 = shardlib.spec_for(mesh, (16, 27), ("embed", "mlp"))  # 27 % 8 != 0
    assert s2[1] is None
    s3 = shardlib.spec_for(mesh, (15, 24), ("embed", "mlp"))  # 15 % 4 != 0
    assert s3[0] is None


def test_spec_for_no_duplicate_axis():
    mesh = _FakeMesh({"data": 4, "model": 8})
    s = shardlib.spec_for(mesh, (8, 16, 24), ("experts", "embed", "mlp"))
    flat = [a for a in s if a is not None]
    exp = []
    for a in flat:
        exp += [a] if isinstance(a, str) else list(a)
    assert len(exp) == len(set(exp))


def test_spec_for_missing_mesh_axis():
    mesh = _FakeMesh({"data": 4})  # no 'model' axis (e.g. DP-only mesh)
    s = shardlib.spec_for(mesh, (16, 24), ("embed", "mlp"))
    assert s == __import__("jax").sharding.PartitionSpec("data", None)


# --------------------------------------------------------------------------
# multi-device subprocess tests
# --------------------------------------------------------------------------


def test_distributed_graph_push(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.graph import generators
from repro.core.dist_engine import partition_graph, make_push_step
g = generators.power_law(300, 2500, seed=5, weighted=True)
mesh = jax.make_mesh((8,), ("data",))
dg = partition_graph(g, mesh)
deg = np.maximum(g.out_degree, 1).astype(np.float32)
rank = np.random.default_rng(0).random(g.n_vertices).astype(np.float32)
prop = np.zeros(dg.n_vertices_padded, np.float32); prop[:g.n_vertices] = rank / deg
step = make_push_step(dg, lambda sv, w: sv, "+")
with mesh:
    out = np.asarray(step(jnp.asarray(prop)))
want = np.zeros_like(prop)
np.add.at(want, g.dst, rank[g.src] / deg[g.src])
np.testing.assert_allclose(out[:g.n_vertices], want[:g.n_vertices], rtol=1e-4)
step2 = make_push_step(dg, lambda sv, w: sv + w, "min")
sp = np.full(dg.n_vertices_padded, np.inf, np.float32)
sp[:g.n_vertices] = np.random.default_rng(1).integers(0, 50, g.n_vertices)
with mesh:
    out2 = np.asarray(step2(jnp.asarray(sp)))
want2 = np.full_like(sp, np.inf)
np.minimum.at(want2, g.dst, sp[g.src] + g.weights)
np.testing.assert_allclose(out2[:g.n_vertices], want2[:g.n_vertices], rtol=1e-5)
print("dist push ok")
"""
    )
    assert "dist push ok" in out


def test_compressed_allreduce(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed.compression import compressed_allreduce
mesh = jax.make_mesh((8,), ("data",))
x = np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32)
def f(shard):
    return compressed_allreduce(shard[0], "data")[None]
g = shard_map(f, mesh=mesh, in_specs=(P("data", None),), out_specs=P("data", None))
with mesh:
    got = np.asarray(jax.jit(g)(jnp.asarray(x)))
want = x.mean(axis=0)
# int8 compression: ~1% relative error on the mean is acceptable
err = np.abs(got - want[None]).max() / (np.abs(want).max() + 1e-9)
assert err < 0.05, err
print("compressed ar ok", err)
"""
    )
    assert "compressed ar ok" in out


def test_sharded_train_step_matches_single_device(subproc):
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.models import Model
from repro.models.layers import set_sharding_rules
from repro.distributed import sharding as shardlib
from repro.train import OptConfig, init_state, make_train_step
from repro.data import SyntheticLM

cfg = smoke_config('qwen3-0.6b')
ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
data = SyntheticLM(cfg, 32, 8, seed=0)
batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

# single device reference
m1 = Model(cfg, dtype=jnp.float32)
p1 = m1.init(jax.random.PRNGKey(0))
s1 = init_state(p1, ocfg)
p1b, _, met1 = jax.jit(make_train_step(m1, ocfg))(p1, s1, batch)

# 2x4 mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
set_sharding_rules({k: shardlib._present(mesh, v) for k, v in shardlib.LOGICAL_RULES.items()}, dict(mesh.shape))
m2 = Model(cfg, dtype=jnp.float32)
p2 = m2.init(jax.random.PRNGKey(0))
psh = shardlib.shardings_of(mesh, shardlib.param_pspecs(mesh, jax.eval_shape(lambda: p2), m2.param_specs()))
with mesh:
    p2 = jax.tree.map(lambda x, s: jax.device_put(x, s), p2, psh)
    s2 = init_state(p2, ocfg)
    p2b, _, met2 = jax.jit(make_train_step(m2, ocfg))(p2, s2, batch)
assert abs(float(met1['loss']) - float(met2['loss'])) < 2e-3, (float(met1['loss']), float(met2['loss']))
for a, b in zip(jax.tree.leaves(p1b), jax.tree.leaves(p2b)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2, atol=5e-4)
print("sharded == single ok")
""",
        devices=8,
        timeout=420,
    )
    assert "sharded == single ok" in out


@pytest.mark.slow
def test_dryrun_small_cell(subproc):
    """One real dry-run cell on a reduced 4x4 host mesh equivalent —
    exercises the production dryrun code path end-to-end."""
    out = subproc(
        """
import repro.launch.dryrun as dr
res = dr.run_cell('xlstm-125m', 'decode_32k', multi_pod=False, phase='gate', verbose=False)
assert res.get('ok'), res
print('cell ok', res['gate']['memory_analysis'].get('argument_size_in_bytes', 0) > 0)
""",
        devices=512,
        timeout=420,
    )
    assert "cell ok" in out


def test_perf_toggles_numerically_equivalent(subproc):
    """The §Perf sharding toggles (chunked attention, 2D batchxseq
    sharding) must not change results under SPMD."""
    out = subproc(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.models import Model
from repro.models.layers import set_sharding_rules
from repro.distributed import sharding as shardlib

cfg = smoke_config('qwen2-vl-2b')
mesh = jax.make_mesh((2, 4), ("data", "model"))
toks = jax.random.randint(jax.random.PRNGKey(0), (4, 64), 0, cfg.vocab_size)
batch = {"embeds": jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model), jnp.float32)}

outs = {}
for name, mkw, seq_rule in [
    ("naive", dict(), None),
    ("chunked", dict(attn_impl="chunked"), None),
    ("sp2d", dict(attn_seq_parallel=True), "model"),
]:
    rules = dict(shardlib.LOGICAL_RULES)
    if seq_rule:
        rules["seq"] = seq_rule
    set_sharding_rules({k: shardlib._present(mesh, v) for k, v in rules.items()},
                       dict(mesh.shape))
    m = Model(cfg, dtype=jnp.float32, **mkw)
    if name == "chunked":
        m.attn_impl = "chunked"
        # exercise the chunk path: chunk smaller than seq
        import repro.models.attention as A
    params = m.init(jax.random.PRNGKey(2))
    psh = shardlib.shardings_of(mesh, shardlib.param_pspecs(mesh, jax.eval_shape(lambda: params), m.param_specs()))
    with mesh:
        p = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
        logits, _ = jax.jit(m.forward)(p, batch)
        outs[name] = np.asarray(logits)
    set_sharding_rules(None)

np.testing.assert_allclose(outs["chunked"], outs["naive"], rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(outs["sp2d"], outs["naive"], rtol=2e-4, atol=2e-5)
print("toggles equivalent ok")
""",
        devices=8,
        timeout=420,
    )
    assert "toggles equivalent ok" in out
