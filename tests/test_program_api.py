"""Program/Session API tests: compile-once caching, parameter validation,
one-program-many-graphs reuse, SessionPool batch serving, and local vs
distributed backend equivalence."""
import gc

import numpy as np
import pytest

import repro
from repro.core import CompileOptions
from repro.core.program import (
    ProgramError,
    clear_program_cache,
    program_cache_size,
)
from repro.core.session import SessionError, SessionPool
from repro.algorithms import sources
from repro.graph import generators


def _counting_src(delta: str) -> str:
    """A tiny degree-counting program; `delta` parameterizes the content."""
    return f"""
element Vertex end
element Edge end
const edges: edgeset{{Edge}}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{{Vertex}} = edges.getVertices();
const acc: vector{{Vertex}}(int);
func initz(v: Vertex)
    acc[v] = 0;
end
func count(src: Vertex, dst: Vertex)
    acc[dst] += {delta};
end
func main()
    vertices.init(initz);
    edges.process(count);
end
"""


REQUIRED_PARAM_SRC = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const mark: vector{Vertex}(int);
const root: int;
func initz(v: Vertex)
    mark[v] = 0;
end
func main()
    vertices.init(initz);
    mark[root] = 1;
end
"""


@pytest.fixture(scope="module")
def graph():
    return generators.power_law(300, 2000, seed=7)


# ---------------------------------------------------------------------------
# Program cache
# ---------------------------------------------------------------------------


def test_compile_cached_on_content():
    clear_program_cache()
    src = _counting_src("1")
    p1 = repro.compile(src)
    # a *distinct string object* with equal content hits the same artifact
    p2 = repro.compile("".join(list(src)))
    assert p1 is p2
    assert program_cache_size() == 1


def test_compile_recompiles_on_different_options():
    src = _counting_src("1")
    p_full = repro.compile(src, CompileOptions.full())
    p_base = repro.compile(src, CompileOptions.baseline())
    assert p_full is not p_base
    assert p_full.options != p_base.options


def test_program_cache_immune_to_id_reuse(graph):
    """Regression for the old id(src)-keyed module cache: after a source
    string is GC'd, CPython may hand its id to an unrelated string, which
    used to alias the two programs. Content-hash keying cannot collide."""
    clear_program_cache()
    ids_seen = []
    for delta in ("1", "2", "3", "1", "2"):
        src = _counting_src(delta)
        ids_seen.append(id(src))
        prog = repro.compile(src)
        res = prog.bind(graph).run()
        np.testing.assert_array_equal(
            res.properties["acc"], graph.in_degree * int(delta)
        )
        del src, prog, res
        gc.collect()  # invite id reuse between iterations
    # three distinct programs live in the cache, never cross-contaminated
    assert program_cache_size() == 3


# ---------------------------------------------------------------------------
# parameter validation
# ---------------------------------------------------------------------------


def test_declared_params_extracted():
    prog = repro.compile(sources.PAGERANK)
    assert set(prog.params) == {"damp", "iters"}
    assert not any(p.required for p in prog.params.values())


def test_unknown_param_raises(graph):
    sess = repro.compile(sources.PAGERANK).bind(graph)
    with pytest.raises(ProgramError, match=r"unknown run-time parameter.*bogus"):
        sess.run(bogus=3)


def test_param_type_mismatch_raises(graph):
    sess = repro.compile(sources.PAGERANK).bind(graph)
    with pytest.raises(ProgramError, match=r"'iters' expects int"):
        sess.run(iters="twenty")
    with pytest.raises(ProgramError, match=r"'iters' expects int"):
        sess.run(iters=2.5)
    # integral floats and numpy ints coerce cleanly
    sess.run(iters=np.int64(2))
    sess.run(iters=3.0)


def test_missing_required_param_raises(graph):
    prog = repro.compile(REQUIRED_PARAM_SRC)
    assert prog.params["root"].required
    sess = prog.bind(graph)
    with pytest.raises(ProgramError, match=r"missing required parameter 'root'"):
        sess.run()
    res = sess.run(root=5)
    assert res.properties["mark"][5] == 1
    assert res.properties["mark"].sum() == 1


def test_unknown_backend_raises(graph):
    prog = repro.compile(sources.PAGERANK)
    with pytest.raises(SessionError, match="unknown backend"):
        prog.bind(graph, backend="fpga")


# ---------------------------------------------------------------------------
# bind-many / run-many
# ---------------------------------------------------------------------------


def test_one_program_many_graphs():
    prog = repro.compile(_counting_src("1"))
    for seed, (v, e) in ((0, (50, 300)), (1, (200, 1500))):
        g = generators.power_law(v, e, seed=seed)
        res = prog.bind(g).run()
        np.testing.assert_array_equal(res.properties["acc"], g.in_degree)


def test_session_reuse_resets_state(graph):
    sess = repro.compile(sources.BFS_ECP, CompileOptions.full()).bind(graph)
    l0 = sess.run(root=0).properties["old_level"]
    l7 = sess.run(root=7).properties["old_level"]
    l0_again = sess.run(root=0).properties["old_level"]
    np.testing.assert_array_equal(l0, l0_again)
    assert not np.array_equal(l0, l7)
    assert sess.runs == 3


def test_deprecated_shims_still_work(graph):
    from repro.core import compile_source, run_source

    module = compile_source(_counting_src("1"))
    assert "count" in module.kernels
    res = run_source(_counting_src("1"), graph)
    np.testing.assert_array_equal(res.properties["acc"], graph.in_degree)


# ---------------------------------------------------------------------------
# SessionPool
# ---------------------------------------------------------------------------


def test_session_pool_batch_order(graph):
    prog = repro.compile(sources.BFS_ECP, CompileOptions.full())
    roots = [0, 3, 9, 0, 42, 7]
    with prog.pool(graph, size=3) as pool:
        results = pool.run_batch([{"root": r} for r in roots])
    assert len(results) == len(roots)
    # results arrive in submission order: each matches a solo session run
    solo = prog.bind(graph)
    for root, res in zip(roots, results):
        want = solo.run(root=root).properties["old_level"]
        np.testing.assert_array_equal(res.properties["old_level"], want)


def test_session_pool_submit_async(graph):
    prog = repro.compile(sources.PAGERANK)
    with SessionPool(prog, graph, size=2) as pool:
        futs = [pool.submit(iters=i) for i in (1, 5)]
        r1, r5 = [f.result() for f in futs]
    assert r1.stats.host_iterations == 1
    assert r5.stats.host_iterations == 5
    with pytest.raises(ProgramError):
        # validation fails fast on the caller thread, even when closed-over
        SessionPool(prog, graph, size=1).submit(nope=1)


# ---------------------------------------------------------------------------
# backend equivalence (acceptance: BFS + PageRank, local == distributed)
# ---------------------------------------------------------------------------


def test_bfs_local_vs_distributed(graph):
    prog = repro.compile(sources.BFS_ECP, CompileOptions.full())
    root = int(np.argmax(graph.out_degree))
    r_local = prog.bind(graph, backend="local").run(root=root)
    r_dist = prog.bind(graph, backend="distributed").run(root=root)
    np.testing.assert_array_equal(
        r_local.properties["old_level"], r_dist.properties["old_level"]
    )
    assert r_dist.stats.dist_supersteps > 0, "edge kernel never distributed"


def test_pagerank_local_vs_distributed(graph):
    prog = repro.compile(sources.PAGERANK)
    r_local = prog.bind(graph, backend="local").run(iters=20)
    r_dist = prog.bind(graph, backend="distributed").run(iters=20)
    np.testing.assert_allclose(
        r_local.properties["rank"], r_dist.properties["rank"], rtol=1e-5
    )
    assert r_dist.stats.dist_supersteps == 20


def test_sssp_distributed_fallback_correct():
    g = generators.power_law(200, 1400, seed=3, weighted=True)
    prog = repro.compile(sources.SSSP, CompileOptions.full())
    r_local = prog.bind(g, backend="local").run(root=0)
    r_dist = prog.bind(g, backend="distributed").run(root=0)
    np.testing.assert_array_equal(r_local.properties["SP"], r_dist.properties["SP"])


def test_distributed_8dev_matches_local(subproc):
    """The real thing: 8 emulated devices, shard_map + all_to_all."""
    out = subproc(
        """
import numpy as np
import repro
from repro.algorithms import sources
from repro.graph import generators

g = generators.power_law(600, 5000, seed=11)
root = int(np.argmax(g.out_degree))
bfs = repro.compile(sources.BFS_ECP, repro.CompileOptions.full())
l_bfs = bfs.bind(g, backend="local").run(root=root)
d_bfs = bfs.bind(g, backend="distributed").run(root=root)
np.testing.assert_array_equal(l_bfs.properties["old_level"],
                              d_bfs.properties["old_level"])
assert d_bfs.stats.dist_supersteps > 0

pr = repro.compile(sources.PAGERANK)
l_pr = pr.bind(g, backend="local").run(iters=15)
d_pr = pr.bind(g, backend="distributed").run(iters=15)
np.testing.assert_allclose(l_pr.properties["rank"], d_pr.properties["rank"],
                           rtol=1e-5)
print("8dev backends agree")
"""
    )
    assert "8dev backends agree" in out
