"""Batched multi-query execution tests (repro.batch + BatchSession).

Covers the acceptance criteria of the batching PR:

* batched results are **bit-identical** to sequential Session.run calls
  for every evaluation algorithm, on both backends, with passes on and
  off, across K in {1, 8, 64} (and a word-boundary-crossing K for the
  bit-packed MS-BFS path);
* the bit-packed multi-source BFS path is selected automatically from the
  MIR template and falls back transparently;
* batched launch totals grow sublinearly in K (<= 0.25 * K x sequential
  for BFS at K=64);
* Session.run_many / SessionPool.run_batch reroute batch-eligible lists
  and fall back on mixed parameter signatures;
* SessionPool stays correct under concurrent submit load, with and
  without the dynamic batch collector (including query counts that are
  not a multiple of the batch size);
* EngineStats reports batch_size instead of passing off per-batch counts
  as per-query.
"""
import threading

import numpy as np
import pytest

import repro
from repro.algorithms import embedded, sources
from repro.batch import DynamicBatcher, match_msbfs
from repro.core import CompileOptions
from repro.core.program import ProgramError
from repro.core.session import ServiceClosed, SessionError
from repro.graph import generators

PASSES_OFF = CompileOptions(passes="none")

# algorithm -> (source, param maker: rng, k -> list of param dicts)
ALGORITHMS = {
    "bfs": (sources.BFS_ECP,
            lambda rng, k: [{"root": int(r)} for r in rng.integers(0, 200, k)]),
    "bfs_hybrid": (sources.BFS_HYBRID,
                   lambda rng, k: [{"root": int(r)} for r in rng.integers(0, 200, k)]),
    "pagerank": (sources.PAGERANK,
                 lambda rng, k: [{"iters": int(i)} for i in rng.integers(2, 8, k)]),
    "sssp": (sources.SSSP,
             lambda rng, k: [{"root": int(r)} for r in rng.integers(0, 200, k)]),
    "ppr": (sources.PPR,
            lambda rng, k: [{"source": int(s), "max_iters": 12}
                            for s in rng.integers(0, 200, k)]),
    "cgaw": (sources.CGAW, lambda rng, k: [{} for _ in range(k)]),
    "wcc": (sources.WCC, lambda rng, k: [{} for _ in range(k)]),
    "kcore": (sources.KCORE,
              lambda rng, k: [{"k": int(v)} for v in rng.integers(2, 5, k)]),
}


@pytest.fixture(scope="module")
def graph():
    return generators.power_law(200, 1400, seed=5, weighted=True)


def assert_results_identical(seq, bat, ctx=""):
    assert len(seq) == len(bat)
    for i, (a, b) in enumerate(zip(seq, bat)):
        assert set(a.properties) == set(b.properties), f"{ctx}[{i}]"
        for name, want in a.properties.items():
            assert np.array_equal(want, b.properties[name]), (
                f"{ctx}[{i}].{name} not bit-identical to the sequential run"
            )
        assert set(a.host_env) == set(b.host_env), f"{ctx}[{i}] host_env keys"
        for name, want in a.host_env.items():
            assert b.host_env[name] == want, f"{ctx}[{i}] host scalar {name}"


# ---------------------------------------------------------------------------
# equivalence matrix: every algorithm x backend x passes, K = 8
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", list(ALGORITHMS), ids=list(ALGORITHMS))
@pytest.mark.parametrize("backend", ["local", "distributed"])
@pytest.mark.parametrize("passes", ["default", "none"], ids=["passes_on", "passes_off"])
def test_batched_equivalence_matrix(graph, algo, backend, passes):
    src, mk = ALGORITHMS[algo]
    opts = CompileOptions(passes=passes)
    prog = repro.compile(src, opts)
    sets = mk(np.random.default_rng(7), 8)
    sess = prog.bind(graph, backend=backend)
    seq = [sess.run(**p) for p in sets]
    bat = prog.bind_batch(graph, backend=backend).run_many(sets)
    assert_results_identical(seq, bat, f"{algo}/{backend}/{passes}")


# ---------------------------------------------------------------------------
# K sweep (acceptance: K in {1, 8, 64}; 40 crosses the packed-word boundary)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["bfs", "pagerank"])
@pytest.mark.parametrize("k", [1, 8, 40, 64])
def test_batched_k_sweep(graph, algo, k):
    src, mk = ALGORITHMS[algo]
    prog = repro.compile(src)
    sets = mk(np.random.default_rng(k), k)
    sess = prog.bind(graph)
    seq = [sess.run(**p) for p in sets]
    bat = prog.bind_batch(graph).run_many(sets)
    assert_results_identical(seq, bat, f"{algo}/K={k}")
    assert bat[0].stats.batch_size == k


def test_batched_bfs_generic_path_matches_msbfs(graph):
    """msbfs=False forces the generic vmapped path onto BFS: same results."""
    prog = repro.compile(sources.BFS_ECP)
    sets = [{"root": int(r)} for r in np.random.default_rng(1).integers(0, 200, 8)]
    fast = prog.bind_batch(graph).run_many(sets)
    generic = prog.bind_batch(graph, msbfs=False).run_many(sets)
    assert_results_identical(fast, generic, "msbfs-vs-vmap")
    from repro.batch.engine import BatchEngine

    assert BatchEngine.MSBFS_NAME in fast[0].stats.kernel_launches
    assert BatchEngine.MSBFS_NAME not in generic[0].stats.kernel_launches


# ---------------------------------------------------------------------------
# MS-BFS template selection
# ---------------------------------------------------------------------------


def test_msbfs_matches_bfs_template():
    for opts in (CompileOptions(), PASSES_OFF):
        plan = match_msbfs(repro.compile(sources.BFS_ECP, opts).module)
        assert plan is not None, f"BFS template should match (passes={opts.passes})"
        assert plan.level_prop == "old_level"
        assert plan.next_prop == "new_level"
        assert plan.tuple_prop == "tuple"
        assert plan.counter_prop == "activeVertex"
        assert plan.root_scalar == "root"
        assert plan.inf == 2147483647
    # the embedded twin produces the same MIR, hence the same plan
    plan = match_msbfs(repro.compile(embedded.build_bfs_ecp()).module)
    assert plan is not None and plan.level_prop == "old_level"


def test_msbfs_rejects_non_bfs_programs():
    # hybrid BFS: direction-switching host `if` breaks the template
    assert match_msbfs(repro.compile(sources.BFS_HYBRID).module) is None
    # PageRank: no dynamic frontier at all
    assert match_msbfs(repro.compile(sources.PAGERANK).module) is None
    assert match_msbfs(repro.compile(sources.SSSP).module) is None


def test_msbfs_declines_when_level_param_overridden(graph):
    """Binding `level` explicitly leaves the template (level must start at
    1) — the engine must fall back to the generic path, still correct."""
    prog = repro.compile(sources.BFS_ECP)
    sets = [{"root": 3, "level": 1}, {"root": 9, "level": 1}]
    sess = prog.bind(graph)
    seq = [sess.run(**p) for p in sets]
    bat = prog.bind_batch(graph).run_many(sets)
    assert_results_identical(seq, bat, "level-override")
    from repro.batch.engine import BatchEngine

    assert BatchEngine.MSBFS_NAME not in bat[0].stats.kernel_launches


# ---------------------------------------------------------------------------
# launch sublinearity (acceptance: <= 0.25 * K x sequential at K = 64)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("msbfs", [True, False], ids=["msbfs", "vmap"])
def test_bfs_launch_sublinearity_at_k64(graph, msbfs):
    prog = repro.compile(sources.BFS_ECP)
    roots = [{"root": int(r)}
             for r in np.random.default_rng(2).integers(0, 200, 64)]
    sess = prog.bind(graph)
    seq_total = sum(sess.run(**p).stats.total_launches for p in roots)
    bat = prog.bind_batch(graph, msbfs=msbfs).run_many(roots)
    batched_total = bat[0].stats.total_launches
    assert batched_total <= 0.25 * seq_total, (
        f"batched BFS used {batched_total} launches vs {seq_total} sequential"
    )


def test_pagerank_launch_sublinearity(graph):
    prog = repro.compile(sources.PAGERANK)
    sets = [{"iters": 6}] * 16
    sess = prog.bind(graph)
    seq_total = sum(sess.run(**p).stats.total_launches for p in sets)
    bat = prog.bind_batch(graph).run_many(sets)
    # identical iteration counts: the batch needs exactly 1/16th the launches
    assert bat[0].stats.total_launches * 16 == seq_total


# ---------------------------------------------------------------------------
# EngineStats batch accounting
# ---------------------------------------------------------------------------


def test_stats_batch_size_and_per_query(graph):
    prog = repro.compile(sources.PAGERANK)
    seq = prog.bind(graph).run(iters=4)
    assert seq.stats.batch_size == 1
    assert seq.stats.per_query_launches == seq.stats.total_launches
    bat = prog.bind_batch(graph).run_many([{"iters": 4}] * 8)
    stats = bat[0].stats
    assert stats.batch_size == 8
    # all results of one batch share one stats object — per-batch counters
    # are explicitly labeled, never silently presented as per-query
    assert all(r.stats is stats for r in bat)
    assert stats.per_query_launches == stats.total_launches / 8


# ---------------------------------------------------------------------------
# Session.run_many rerouting
# ---------------------------------------------------------------------------


def test_run_many_reroutes_eligible_sets(graph):
    prog = repro.compile(sources.PAGERANK)
    sess = prog.bind(graph)
    sets = [{"iters": int(i)} for i in (3, 5, 7, 9)]
    seq = [prog.bind(graph).run(**p) for p in sets]
    got = sess.run_many(sets)
    assert sess._batch_session is not None, "eligible list should batch"
    assert_results_identical(seq, got, "run_many")
    assert got[0].stats.batch_size == 4


def test_run_many_falls_back_on_mixed_signatures(graph):
    prog = repro.compile(sources.PAGERANK)
    sess = prog.bind(graph)
    sets = [{"iters": 3}, {"damp": 0.9}]  # different key sets
    got = sess.run_many(sets)
    assert sess._batch_session is None, "mixed signatures must not batch"
    assert got[0].stats.batch_size == 1
    seq = [prog.bind(graph).run(**p) for p in sets]
    assert_results_identical(seq, got, "run_many-mixed")


def test_run_many_batched_flag(graph):
    prog = repro.compile(sources.PAGERANK)
    sess = prog.bind(graph)
    sets = [{"iters": 3}, {"iters": 4}]
    forced_seq = sess.run_many(sets, batched=False)
    assert forced_seq[0].stats.batch_size == 1
    forced_bat = sess.run_many(sets, batched=True)
    assert forced_bat[0].stats.batch_size == 2
    assert_results_identical(forced_seq, forced_bat, "batched-flag")
    with pytest.raises(SessionError):
        sess.run_many([{"iters": 3}, {"damp": 0.9}], batched=True)


def test_batch_session_validation(graph):
    prog = repro.compile(sources.PAGERANK)
    bs = prog.bind_batch(graph)
    assert bs.run_many([]) == []
    with pytest.raises(ProgramError):
        bs.run_many([{"nope": 1}])
    with pytest.raises(SessionError):
        bs.run_many([{"iters": 3}, {"damp": 0.9}])


def test_bind_batch_max_batch_chunks(graph):
    prog = repro.compile(sources.PAGERANK)
    bs = prog.bind_batch(graph, max_batch=3)
    got = bs.run_many([{"iters": 4}] * 7)  # 3 + 3 + 1
    assert len(got) == 7
    assert bs.runs == 3
    sizes = sorted({r.stats.batch_size for r in got})
    assert sizes == [1, 3]


# ---------------------------------------------------------------------------
# SessionPool: rerouting, concurrency, dynamic batch collector
# ---------------------------------------------------------------------------


def test_pool_run_batch_reroutes(graph):
    prog = repro.compile(sources.PAGERANK)
    sets = [{"iters": int(i)} for i in (3, 4, 5, 6)]
    seq = [prog.bind(graph).run(**p) for p in sets]
    with prog.pool(graph, size=2) as pool:
        got = pool.run_batch(sets)
    assert_results_identical(seq, got, "pool-batched")
    assert got[0].stats.batch_size == 4
    with prog.pool(graph, size=2) as pool:
        got_seq = pool.run_batch(sets, batched=False)
    assert_results_identical(seq, got_seq, "pool-sequential")
    assert got_seq[0].stats.batch_size == 1


def test_pool_concurrent_submit_thread_safety(graph):
    """Hammer acquire/release from many threads; every result must match
    its own dedicated sequential run."""
    prog = repro.compile(sources.PAGERANK)
    iters = [2 + (i % 5) for i in range(24)]
    want = {it: prog.bind(graph).run(iters=it) for it in sorted(set(iters))}
    with prog.pool(graph, size=3) as pool:
        results = [None] * len(iters)
        errors = []

        def worker(i):
            try:
                results[i] = pool.submit(iters=iters[i]).result(timeout=120)
            except Exception as exc:  # pragma: no cover - failure reporting
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(iters))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
    assert not errors
    for i, it in enumerate(iters):
        assert results[i] is not None
        assert np.array_equal(results[i].properties["rank"],
                              want[it].properties["rank"])


def test_pool_dynamic_batcher_non_multiple_batch(graph):
    """batch=4 with 10 concurrent queries: the collector forms partial
    batches as needed and every Future resolves to the right answer."""
    prog = repro.compile(sources.PAGERANK)
    iters = [2 + (i % 3) for i in range(10)]
    want = {it: prog.bind(graph).run(iters=it) for it in sorted(set(iters))}
    with prog.pool(graph, size=2, batch=4, batch_wait_s=0.05) as pool:
        futures = [pool.submit(iters=it) for it in iters]
        results = [f.result(timeout=180) for f in futures]
        stats = pool.batch_stats
    assert stats is not None
    assert stats.queries == 10
    assert sum(stats.sizes) == 10
    assert all(1 <= s <= 4 for s in stats.sizes)
    assert 0.0 < stats.occupancy <= 1.0
    for it, res in zip(iters, results):
        assert np.array_equal(res.properties["rank"], want[it].properties["rank"])
        assert res.host_env["iters"] == it


def test_dynamic_batcher_splits_mixed_signatures():
    """One batch = one parameter signature; mixed streams split batches."""
    calls = []

    def run_many(param_sets):
        keys = {frozenset(p) for p in param_sets}
        assert len(keys) == 1, "batcher handed down a mixed batch"
        calls.append(len(param_sets))
        return [dict(p) for p in param_sets]

    b = DynamicBatcher(run_many, max_batch=8, max_wait_s=0.05)
    futs = [b.submit({"root": i}) for i in range(3)]
    futs += [b.submit({"iters": i}) for i in range(2)]
    futs += [b.submit({"root": 9})]
    out = [f.result(timeout=60) for f in futs]
    b.close()
    assert out[0] == {"root": 0} and out[3] == {"iters": 0} and out[5] == {"root": 9}
    assert sum(calls) == 6


def test_dynamic_batcher_propagates_errors():
    def run_many(param_sets):
        raise ValueError("boom")

    b = DynamicBatcher(run_many, max_batch=4, max_wait_s=0.01)
    fut = b.submit({"x": 1})
    with pytest.raises(ValueError):
        fut.result(timeout=60)
    b.close()
    with pytest.raises(ServiceClosed):
        b.submit({"x": 2})


# ---------------------------------------------------------------------------
# embedded front-end + distributed backend through bind_batch
# ---------------------------------------------------------------------------


def test_bind_batch_embedded_front_end(graph):
    """The embedded BFS twin batches identically to its text source."""
    sets = [{"root": int(r)} for r in np.random.default_rng(4).integers(0, 200, 8)]
    text = repro.compile(sources.BFS_ECP).bind_batch(graph).run_many(sets)
    emb = repro.compile(embedded.build_bfs_ecp()).bind_batch(graph).run_many(sets)
    assert_results_identical(text, emb, "embedded")


def test_distributed_batch_still_supersteps(graph):
    """Batched distributed PageRank keeps running shuffle supersteps —
    one vmapped all_to_all round per iteration for the whole batch."""
    prog = repro.compile(sources.PAGERANK)
    bat = prog.bind_batch(graph, backend="distributed").run_many(
        [{"iters": 6}] * 4)
    assert bat[0].stats.dist_supersteps == 6
    assert bat[0].stats.batch_size == 4


# ---------------------------------------------------------------------------
# batched Pallas entry points
# ---------------------------------------------------------------------------


def test_shuffle_reduce_batched_matches_per_row():
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    k, n, n_out = 4, 300, 64
    vals = rng.normal(size=(k, n)).astype(np.float32)
    ivals = rng.integers(-50, 50, (k, n)).astype(np.int32)
    idx = rng.integers(0, n_out, (k, n)).astype(np.int32)
    for op in ("min", "max"):
        got = ops.shuffle_reduce_batched(vals, idx, n_out, op)
        for q in range(k):
            assert np.array_equal(
                np.asarray(got[q]),
                np.asarray(ops.shuffle_reduce(vals[q], idx[q], n_out, op)))
    got = ops.shuffle_reduce_batched(ivals, idx, n_out, "+")
    for q in range(k):
        assert np.array_equal(
            np.asarray(got[q]),
            np.asarray(ops.shuffle_reduce(ivals[q], idx[q], n_out, "+")))
    # shared idx broadcasting + float sums (tile regrouping: allclose)
    got = ops.shuffle_reduce_batched(vals, idx[0], n_out, "+")
    for q in range(k):
        np.testing.assert_allclose(
            np.asarray(got[q]),
            np.asarray(ops.shuffle_reduce(vals[q], idx[0], n_out, "+")),
            rtol=1e-5, atol=1e-5)


def test_edge_stream_batched_matches_per_row():
    from repro.kernels import ops

    rng = np.random.default_rng(1)
    k, n, n_out = 3, 400, 64
    sv = rng.normal(size=(k, n)).astype(np.float32)
    w = rng.normal(size=(n,)).astype(np.float32)
    dst = rng.integers(0, n_out, (n,)).astype(np.int32)
    act = rng.integers(0, 2, (n,)).astype(bool)
    for red in ("min", "max"):
        got = ops.edge_stream_batched(sv, w, dst, act, n_out, "add", red)
        for q in range(k):
            assert np.array_equal(
                np.asarray(got[q]),
                np.asarray(ops.edge_stream(sv[q], w, dst, act, n_out, "add", red)))
