"""MIR optimization pass pipeline tests (repro.core.passes).

Covers the acceptance criteria of the pass-pipeline PR:
* passes-on vs passes-off produce identical results for every evaluation
  algorithm on BOTH execution backends (local and distributed);
* BFS + PageRank show >= 1.3x kernel-launch reduction via EngineStats;
* golden Module.describe() snapshots pin which kernels fused, which
  direction each edge kernel was assigned, and what dce/fold removed;
* CompileOptions.passes participates in the Program cache key.
"""
import numpy as np
import pytest

import repro
from repro.core import CompileOptions, PassError
from repro.core import mir
from repro.core.passes import DEFAULT_PASSES, parse_pass_list
from repro.core.program import ProgramError
from repro.algorithms import sources
from repro.graph import generators

PASSES_OFF = CompileOptions(passes="none")

ALGORITHMS = {
    "bfs": (sources.BFS_ECP, {"root": 0}),
    "bfs_hybrid": (sources.BFS_HYBRID, {"root": 0}),
    "pagerank": (sources.PAGERANK, {"iters": 6}),
    "sssp": (sources.SSSP, {"root": 0}),
    "ppr": (sources.PPR, {"max_iters": 20}),
    "cgaw": (sources.CGAW, {}),
    "wcc": (sources.WCC, {}),
    "kcore": (sources.KCORE, {"k": 2}),
}


@pytest.fixture(scope="module")
def graph():
    return generators.power_law(200, 1400, seed=5, weighted=True)


# ---------------------------------------------------------------------------
# equivalence: identical results with passes on vs off, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", list(ALGORITHMS), ids=list(ALGORITHMS))
@pytest.mark.parametrize("backend", ["local", "distributed"])
def test_passes_preserve_results(graph, algo, backend):
    src, params = ALGORITHMS[algo]
    r_on = repro.compile(src, CompileOptions.full()).bind(
        graph, backend=backend).run(**params)
    r_off = repro.compile(src, PASSES_OFF).bind(
        graph, backend=backend).run(**params)
    assert set(r_on.properties) == set(r_off.properties)
    for name, want in r_off.properties.items():
        np.testing.assert_allclose(
            r_on.properties[name], want, rtol=1e-5,
            err_msg=f"{algo}/{backend}/{name} diverged with passes enabled",
        )


# ---------------------------------------------------------------------------
# acceptance: >= 1.3x launch reduction on BFS + PageRank, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["bfs", "pagerank"])
@pytest.mark.parametrize("backend", ["local", "distributed"])
def test_launch_reduction_floor(graph, algo, backend):
    src, params = ALGORITHMS[algo]
    if algo == "bfs":  # a reachable frontier exercises the full iteration loop
        params = {"root": int(np.argmax(graph.out_degree))}
    r_on = repro.compile(src, CompileOptions.full()).bind(
        graph, backend=backend).run(**params)
    r_off = repro.compile(src, PASSES_OFF).bind(
        graph, backend=backend).run(**params)
    on = r_on.stats.total_launches
    off = r_off.stats.total_launches
    assert off / on >= 1.3, f"{algo}/{backend}: only {off / on:.2f}x reduction"
    assert r_on.stats.fused_launches > 0
    # fusion is the only pass that changes launch counts: the saved-launch
    # counter must account for the entire difference
    assert r_on.stats.launches_saved == off - on
    assert r_off.stats.fused_launches == 0


def test_distributed_still_supersteps_fused_pipelines(graph):
    """The distributed engine consumes a fused edge->vertex pipeline by
    running its edge stage as a shuffle superstep, not by degrading to a
    purely local launch."""
    prog = repro.compile(sources.PAGERANK, CompileOptions.full())
    res = prog.bind(graph, backend="distributed").run(iters=6)
    assert res.stats.dist_supersteps == 6
    assert res.stats.fused_launches == 6


# ---------------------------------------------------------------------------
# golden describe() snapshots: the pass report is part of the artifact
# ---------------------------------------------------------------------------


def test_describe_reports_pagerank_pipeline():
    text = repro.compile(sources.PAGERANK, CompileOptions.full()).describe()
    assert "kernel computeContrib__applyRank [pipeline: computeContrib -> applyRank]" in text
    assert "pass direction: computeContrib -> dense (loop-invariant guard on ['deg'])" in text
    assert ("pass fuse: computeContrib + applyRank -> computeContrib__applyRank "
            "(pipeline [edge -> vertex])") in text


def test_describe_reports_bfs_fusion_and_direction():
    text = repro.compile(sources.BFS_ECP, CompileOptions.full()).describe()
    assert "kernel VertexUpdate__VertexApply [vertex]" in text
    assert ("pass fuse: VertexUpdate + VertexApply -> VertexUpdate__VertexApply "
            "(merged vertex kernel)") in text
    assert "pass direction: EdgeTraversal -> sparse (dynamic frontier on ['old_level'])" in text
    assert "direction sparse" in text


def test_describe_without_passes_has_no_report():
    text = repro.compile(sources.PAGERANK, PASSES_OFF).describe()
    assert "pass " not in text
    assert "pipeline" not in text


# ---------------------------------------------------------------------------
# fuse pass unit behaviour
# ---------------------------------------------------------------------------


def test_fusion_groups_recorded():
    prog = repro.compile(sources.BFS_ECP, CompileOptions.full())
    assert prog.module.fusion_groups == {
        "VertexUpdate__VertexApply": ("VertexUpdate", "VertexApply"),
    }
    # original kernels stay addressable (other sites may launch them solo)
    assert "VertexUpdate" in prog.module.kernels
    assert "VertexApply" in prog.module.kernels


def test_no_fusion_from_vertex_into_edge_kernel(graph):
    """A group never extends vertex -> edge: `vertices.init(initz);
    edges.process(count)` keeps two separate launches (the Fig. 4 pipeline
    shape is edge traversal -> vertex apply, not init -> traversal)."""
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const acc: vector{Vertex}(int);
func initz(v: Vertex)
    acc[v] = 0;
end
func count(src: Vertex, dst: Vertex)
    acc[dst] += 1;
end
func main()
    vertices.init(initz);
    edges.process(count);
end
"""
    prog = repro.compile(src, CompileOptions.full())
    assert prog.module.fusion_groups == {}
    res = prog.bind(graph).run()
    assert res.stats.kernel_launches == {"initz": 1, "count": 1}
    np.testing.assert_array_equal(res.properties["acc"], graph.in_degree)


def test_sparse_edge_kernel_not_fused_keeps_compaction(graph):
    """BFS's EdgeTraversal has a dynamic frontier: it must stay a
    standalone launch so the engine can frontier-compact it."""
    prog = repro.compile(sources.BFS_ECP, CompileOptions.full())
    assert prog.module.kernels["EdgeTraversal"].direction is mir.Direction.SPARSE
    res = prog.bind(graph).run(root=int(np.argmax(graph.out_degree)))
    assert "EdgeTraversal" in res.stats.kernel_launches
    assert res.stats.compacted_launches > 0


def test_cgaw_edge_edge_pipeline(graph):
    """Adjacent edge kernels (score; normalize) fuse into one pipeline —
    stage-boundary commits keep the weight read-after-write exact."""
    prog = repro.compile(sources.CGAW, CompileOptions.full())
    assert prog.module.fusion_groups.get("score__normalize") == ("score", "normalize")
    kern = prog.module.kernels["score__normalize"]
    assert isinstance(kern, mir.PipelineKernel)
    assert [s.name for s in kern.edge_stages] == ["score", "normalize"]


# ---------------------------------------------------------------------------
# direction pass unit behaviour
# ---------------------------------------------------------------------------


def test_direction_assignments():
    m = repro.compile(sources.PAGERANK, CompileOptions.full()).module
    assert m.kernels["computeContrib"].direction is mir.Direction.DENSE
    m = repro.compile(sources.SSSP, CompileOptions.full()).module
    assert m.kernels["relax"].direction is mir.Direction.SPARSE
    # passes off: the engine keeps its runtime-only fallback heuristic
    m = repro.compile(sources.PAGERANK, PASSES_OFF).module
    assert m.kernels["computeContrib"].direction is mir.Direction.AUTO


def test_dense_direction_skips_frontier_mask(graph):
    """A DENSE verdict must eliminate the per-launch host-side frontier
    mask evaluation (PageRank's deg[src] > 0 guard is loop-invariant)."""
    prog = repro.compile(sources.PAGERANK, CompileOptions(passes="direction"))
    res = prog.bind(graph).run(iters=5)
    assert res.stats.compacted_launches == 0
    assert res.stats.full_launches > 0


# ---------------------------------------------------------------------------
# fold pass: compile-time scalar bindings
# ---------------------------------------------------------------------------


def test_scalar_binding_specializes_and_removes_param(graph):
    opts = CompileOptions(scalar_bindings=(("damp", 0.85),))
    prog = repro.compile(sources.PAGERANK, opts)
    assert "damp" not in prog.params
    assert any(l.startswith("fold: bound scalar damp") for l in prog.module.pass_report)
    want = repro.compile(sources.PAGERANK, PASSES_OFF).bind(graph).run(iters=6)
    got = prog.bind(graph).run(iters=6)
    np.testing.assert_allclose(got.properties["rank"], want.properties["rank"], rtol=1e-6)
    with pytest.raises(ProgramError, match="unknown run-time parameter"):
        prog.bind(graph).run(damp=0.5)


def test_binding_unknown_or_host_mutated_scalar_raises():
    with pytest.raises(PassError, match="not a declared host scalar"):
        repro.compile(sources.PAGERANK, CompileOptions(scalar_bindings=(("nope", 1),)))
    # BFS's `level` is incremented by the host loop: binding it is unsound
    with pytest.raises(PassError, match="host program assigns it"):
        repro.compile(sources.BFS_ECP, CompileOptions(scalar_bindings=(("level", 1),)))


def test_binding_substitutes_into_other_scalar_inits(graph):
    """A bound scalar referenced by ANOTHER scalar's initializer must be
    substituted there too (the engine evaluates inits at construction)."""
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const mark: vector{Vertex}(int);
const k: int = 3;
const kk: int = k * 2;
func initz(v: Vertex)
    mark[v] = kk;
end
func main()
    vertices.init(initz);
end
"""
    prog = repro.compile(src, CompileOptions(scalar_bindings=(("k", 5),)))
    assert "k" not in prog.params and "kk" in prog.params
    res = prog.bind(graph).run()
    np.testing.assert_array_equal(res.properties["mark"], 10)


def test_binding_without_fold_pass_raises():
    """scalar_bindings must never be silently ignored: a pipeline that
    omits `fold` cannot honor the requested specialization."""
    for spec in ("none", "dce,fuse"):
        with pytest.raises(PassError, match="requires the 'fold' pass"):
            repro.compile(
                sources.PAGERANK,
                CompileOptions(passes=spec, scalar_bindings=(("damp", 0.5),)),
            )


# ---------------------------------------------------------------------------
# dce pass: dead properties, scalars, and folded-empty kernels
# ---------------------------------------------------------------------------

DCE_SRC = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const acc: vector{Vertex}(int);
const unused: vector{Vertex}(float);
const ghost: int = 7;
const flag: bool = false;
func initz(v: Vertex)
    acc[v] = 0;
end
func gated(v: Vertex)
    if (flag)
        acc[v] = 99;
    end
end
func count(src: Vertex, dst: Vertex)
    acc[dst] += 1;
end
func main()
    vertices.init(initz);
    vertices.process(gated);
    edges.process(count);
end
"""


def test_dce_removes_dead_buffers_scalars_and_kernels(graph):
    prog = repro.compile(DCE_SRC, CompileOptions(scalar_bindings=(("flag", False),)))
    m = prog.module
    assert "unused" not in m.properties and "unused" not in m.memory.buffers
    assert "ghost" not in m.scalars
    assert "gated" not in m.kernels  # body folded to nothing -> launch removed
    # channels renumbered densely over the surviving buffers
    assert [b[2] for b in m.memory.buffers.values()] == list(range(len(m.memory.buffers)))
    res = prog.bind(graph).run()
    np.testing.assert_array_equal(res.properties["acc"], graph.in_degree)
    assert "unused" not in res.properties
    assert "gated" not in res.stats.kernel_launches


def test_dce_keeps_write_only_outputs(graph):
    """Properties that are written but never read are observable results
    (e.g. accumulators surfaced via EngineResult) — never eliminated."""
    prog = repro.compile(DCE_SRC, CompileOptions.full())
    assert "acc" in prog.module.properties


def test_dce_keeps_write_only_scalar_and_chained_inits(graph):
    """Write-only scalars are observable via EngineResult.host_env (like
    write-only property buffers) — kept. And a scalar referenced only by
    ANOTHER scalar's initializer is a genuine use — also kept."""
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const acc: vector{Vertex}(int);
const base: int = 3;
const derived: int = base + 1;
const wonly: int = 0;
func count(src: Vertex, dst: Vertex)
    acc[dst] += 1;
end
func main()
    wonly = derived;
    edges.process(count);
end
"""
    prog = repro.compile(src, CompileOptions.full())
    assert {"base", "derived", "wonly"} <= set(prog.module.scalars)
    res = prog.bind(graph).run()
    assert res.host_env["wonly"] == 4
    np.testing.assert_array_equal(res.properties["acc"], graph.in_degree)
    # the same program with passes off agrees on the observable surface
    res_off = repro.compile(src, PASSES_OFF).bind(graph).run()
    assert res_off.host_env["wonly"] == res.host_env["wonly"]


def test_kernel_comparisons_fold_with_float32_semantics(graph):
    """Literal comparisons in kernel bodies must fold the way the DEVICE
    compares (float32): 0.1 + 0.2 == 0.3 is True in float32 but False in
    float64 — folding with host semantics would delete a live branch."""
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const mark: vector{Vertex}(int);
func initz(v: Vertex)
    if (0.1 + 0.2 == 0.3)
        mark[v] = 1;
    end
end
func main()
    vertices.init(initz);
end
"""
    for opts in (CompileOptions.full(), PASSES_OFF):
        res = repro.compile(src, opts).bind(graph).run()
        assert res.properties["mark"][0] == 1, f"f32-equal branch lost ({opts.passes})"


def test_host_expressions_not_folded_with_device_semantics(graph):
    """Host code evaluates in Python float64; the fold pass must not
    simplify host arithmetic with device float32 semantics. 16777216.0 +
    1.0 is exact in float64 but rounds away in float32."""
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const mark: vector{Vertex}(int);
func initz(v: Vertex)
    mark[v] = 0;
end
func main()
    vertices.init(initz);
    var hit: int = 0;
    if (16777216.0 + 1.0 > 16777216.5)
        hit = 1;
    end
    mark[0] = hit;
end
"""
    for opts in (CompileOptions.full(), PASSES_OFF):
        res = repro.compile(src, opts).bind(graph).run()
        assert res.properties["mark"][0] == 1, f"host float64 branch lost ({opts.passes})"


# ---------------------------------------------------------------------------
# options / cache-key plumbing
# ---------------------------------------------------------------------------


def test_passes_participate_in_cache_key():
    a = repro.compile(sources.PAGERANK, CompileOptions.full())
    b = repro.compile(sources.PAGERANK, PASSES_OFF)
    assert a is not b and a.fingerprint != b.fingerprint
    # and the base module cache stays pristine for other option sets
    assert "computeContrib__applyRank" not in b.module.kernels


def test_parse_pass_list():
    assert parse_pass_list("default") == DEFAULT_PASSES
    assert parse_pass_list("none") == ()
    assert parse_pass_list("fold, fuse") == ("fold", "fuse")
    with pytest.raises(PassError, match="unknown pass"):
        parse_pass_list("bogus")


def test_baseline_options_disable_passes():
    assert CompileOptions.baseline().passes == "none"
    assert CompileOptions.full().passes == "default"


def test_interpret_defaults_to_auto():
    opts = CompileOptions.full(pallas=True)
    assert opts.interpret is None  # auto
    # on CPU/GPU hosts auto resolves to interpreted Pallas
    import jax

    expected = jax.default_backend() != "tpu"
    assert opts.interpret_effective is expected
    assert CompileOptions(interpret=False).interpret_effective is False
    assert CompileOptions(interpret=True).interpret_effective is True
