"""Soft dependency gate for `hypothesis`.

When hypothesis is installed, this module re-exports the real API. When it
is missing (the CI base image does not bake it in), `@given` tests become
individual skips while every other test in the importing module still
collects and runs — instead of the whole file dying at import time.
"""
import pytest

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            # deliberately NOT functools.wraps: the replacement must keep
            # its own (*a, **k) signature so pytest doesn't try to resolve
            # the strategy-bound parameters as fixtures
            def skipper(*a, **k):
                pytest.skip("hypothesis is not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    class _AnyStrategy:
        """Accepts any strategy constructor call (st.integers(...), ...)."""

        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return None

            return strategy

    strategies = _AnyStrategy()
