"""Static analysis framework: repro.analyze + lint + admission gates.

Acceptance criteria of the static-analysis PR:

* all 8 built-in algorithms lint clean (0 errors, 0 warnings) with the
  expected determinism certificates;
* a deliberately racy edge kernel (plain ``=`` scatter to ``P[dst]``) is
  flagged GT101 with correct provenance on BOTH front-ends — a caret
  excerpt into the ``.gt`` text, ``file.py:lineno`` for the embedded
  twin — and the diagnostic *codes* are identical across front-ends
  (the parity matrix);
* ``repro.compile(src, strict=True)`` raises :class:`ProgramError` on
  error-level diagnostics (on the fresh AND the cache-hit path);
  ``GraphService.submit`` rejects with typed :class:`ProgramRejected`
  before registry admission and counts ``rejections_analysis`` per
  tenant;
* the GT101 verdict feeds execution: an Engine over a racy module forces
  the shuffle substrate back on even under ``CompileOptions.baseline()``,
  and the deterministic last-write-wins scatter path matches sequential
  edge-order semantics;
* ``accelerator.report()`` and saved artifact manifests carry the
  determinism certificate;
* GT3xx/GT4xx/GT5xx dataflow analyses fire on targeted programs and stay
  quiet on the shipped algorithms;
* ``python -m repro.lint`` exits 0/1 and emits well-formed ``--json``.
"""
import json
import os

import numpy as np
import pytest

import repro
from repro import analysis
from repro.algorithms import sources
from repro.algorithms.embedded import BFS_ECP_EMBEDDED, PAGERANK_EMBEDDED
from repro.core.accelerator import GraphShape
from repro.frontend import GraphProgram
from repro.graph.storage import GraphData


RACY_GT = """
element Vertex end
const edges: edgeset{Vertex}(Vertex, Vertex) = load(argv(1));
const vertices: vertexset{Vertex};
const P: vector{Vertex}(int);
func initP(v: Vertex)
    P[v] = 0;
end
func upd(src: Vertex, dst: Vertex)
    P[dst] = P[src] + 1;
end
func main()
    vertices.init(initP);
    edges.process(upd);
end
"""


def build_racy_embedded() -> GraphProgram:
    """The embedded twin of RACY_GT (same kernels, same race)."""
    g = GraphProgram("racy_twin")
    edges = g.edgeset("edges")
    vertices = g.vertexset("vertices")
    P = g.vertex_prop("P", int)

    @g.vertex_kernel
    def initP(v):
        P[v] = 0

    @g.edge_kernel
    def upd(src, dst):
        P[dst] = P[src] + 1

    @g.main
    def main():
        vertices.init(initP)
        edges.process(upd)

    return g


def tiny_graph() -> GraphData:
    return GraphData(4, src=[0, 1, 2, 0], dst=[1, 2, 0, 2])


# ---------------------------------------------------------------------------
# built-in algorithms: all clean, expected certificates
# ---------------------------------------------------------------------------

EXPECTED_CERTS = {
    "BFS_ECP": analysis.DETERMINISTIC,
    "BFS_HYBRID": analysis.DETERMINISTIC,
    "SSSP": analysis.DETERMINISTIC,
    "WCC": analysis.DETERMINISTIC,
    "KCORE": analysis.DETERMINISTIC,
    "PAGERANK": analysis.REDUCTION_DETERMINISTIC,
    "PPR": analysis.REDUCTION_DETERMINISTIC,
    "CGAW": analysis.REDUCTION_DETERMINISTIC,
}


@pytest.mark.parametrize("name", sorted(EXPECTED_CERTS))
def test_builtin_algorithm_lints_clean(name):
    res = analysis.analyze(getattr(sources, name))
    assert res.errors == (), [d.format() for d in res.errors]
    assert res.warnings == (), [d.format() for d in res.warnings]
    assert res.certificate == EXPECTED_CERTS[name]
    # the certificate + incremental verdict always ride along as infos
    codes = res.codes()
    assert "GT201" in codes and "GT202" in codes


def test_builtins_never_need_forced_shuffle():
    from repro.core.program import compile_program

    for name in EXPECTED_CERTS:
        prog = compile_program(getattr(sources, name))
        assert not analysis.needs_shuffle(prog.module), name


# ---------------------------------------------------------------------------
# the parity matrix: same codes on both front-ends, provenance differs
# ---------------------------------------------------------------------------


def test_racy_parity_codes_match_across_front_ends():
    text = analysis.analyze(RACY_GT)
    emb = analysis.analyze(build_racy_embedded())
    assert text.codes() == emb.codes()
    assert "GT101" in text.codes()
    assert text.certificate == emb.certificate == analysis.RACY


def test_racy_text_provenance_is_caret_excerpt():
    res = analysis.analyze(RACY_GT)
    (err,) = res.errors
    assert err.code == "GT101"
    assert err.kernel == "upd" and err.prop == "P"
    # the caret excerpt quotes the racy line of the .gt text
    assert "P[dst] = P[src] + 1;" in err.location
    assert "^" in err.location
    assert err.line == RACY_GT.splitlines().index(
        "    P[dst] = P[src] + 1;") + 1


def test_racy_embedded_provenance_is_python_file_lineno():
    res = analysis.analyze(build_racy_embedded())
    (err,) = res.errors
    assert err.code == "GT101"
    # rendered as this very file + the absolute lineno of the racy write
    assert err.location.startswith(os.path.abspath(__file__).rsplit(os.sep, 1)[-1]) \
        or __file__.rsplit(os.sep, 1)[-1] in err.location
    assert err.location.endswith(f":{err.line}")
    src_line = open(__file__).read().splitlines()[err.line - 1]
    assert "P[dst] = P[src] + 1" in src_line


def test_analyze_never_raises_on_broken_source():
    res = analysis.analyze("func main( end")
    assert not res.ok
    assert res.certificate == "unknown"
    assert res.errors[0].code in ("GT001", "GT002")


def test_program_diagnostics_method():
    prog = repro.compile(RACY_GT)
    res = prog.diagnostics()
    assert "GT101" in res.codes()
    assert res.fingerprint == prog.fingerprint
    # cached: same object on repeat calls
    assert prog.diagnostics() is res


# ---------------------------------------------------------------------------
# strict compile + serving admission
# ---------------------------------------------------------------------------


def test_strict_compile_rejects_racy_program():
    with pytest.raises(repro.ProgramError) as ei:
        repro.compile(RACY_GT, strict=True)
    assert "GT101" in str(ei.value)
    # cache-hit path must reject too (non-strict compile primes the cache)
    assert repro.compile(RACY_GT) is not None
    with pytest.raises(repro.ProgramError):
        repro.compile(RACY_GT, strict=True)
    # strict passes a clean program through
    assert repro.compile(sources.BFS_ECP, strict=True) is not None


def test_service_submit_rejects_racy_both_front_ends():
    g = tiny_graph()
    with repro.serve(registry_dir=False) as svc:
        for program in (RACY_GT, build_racy_embedded()):
            with pytest.raises(repro.ProgramRejected) as ei:
                svc.submit(program, g, tenant="alice")
            assert [d.code for d in ei.value.diagnostics] == ["GT101"]
        stats = svc.stats()
        assert stats["tenants"]["alice"]["rejections_analysis"] == 2
        assert stats["queries"]["rejections_analysis"] == 2
        # a clean program on the same service still runs
        res = svc.run("bfs", g, tenant="alice", root=0)
        assert res is not None
        assert stats["tenants"]["alice"]["rejected_overloaded"] == 0


def test_program_rejected_is_typed_serving_error():
    assert issubclass(repro.ProgramRejected, repro.ServingError)


# ---------------------------------------------------------------------------
# the verdict feeds execution: forced shuffle + deterministic stores
# ---------------------------------------------------------------------------


def test_engine_forces_shuffle_on_racy_module():
    prog = repro.compile(RACY_GT, repro.CompileOptions.baseline())
    sess = prog.bind(tiny_graph())
    eng = sess.backend.engine
    assert eng.shuffle_forced is True
    assert eng.target.shuffle is True


def test_engine_does_not_force_shuffle_on_clean_module():
    prog = repro.compile(sources.BFS_ECP, repro.CompileOptions.baseline())
    sess = prog.bind(tiny_graph())
    eng = sess.backend.engine
    assert eng.shuffle_forced is False
    assert eng.target.shuffle is False


def test_plain_scatter_is_last_write_wins_in_edge_order():
    # under the deterministic path P[dst] must hold the LAST writing
    # edge's value in CSR stream order (src-major), exactly like a
    # sequential loop over the streamed edges. cache=False keeps vertex
    # ids untranslated so the stored `src` values are directly readable.
    src = """
element Vertex end
const edges: edgeset{Vertex}(Vertex, Vertex) = load(argv(1));
const vertices: vertexset{Vertex};
const P: vector{Vertex}(int);
func initP(v: Vertex)
    P[v] = -1;
end
func upd(src: Vertex, dst: Vertex)
    P[dst] = src;
end
func main()
    vertices.init(initP);
    edges.process(upd);
end
"""
    g = GraphData(4, src=[0, 1, 2, 0], dst=[2, 2, 0, 2])
    prog = repro.compile(src)
    res = prog.bind(g, target=repro.Target(cache=False)).run()
    P = np.asarray(res.properties["P"])
    # CSR stream order is [0->2, 0->2, 1->2, 2->0]: the last edge
    # writing vertex 2 has src 1
    assert P[2] == 1
    assert P[0] == 2  # only edge (2->0) writes vertex 0
    assert P[1] == -1  # never written, keeps its init
    assert P[3] == -1


# ---------------------------------------------------------------------------
# dataflow analyses: GT3xx / GT4xx / GT5xx
# ---------------------------------------------------------------------------

NONTERM_GT = """
element Vertex end
const edges: edgeset{Vertex}(Vertex, Vertex) = load(argv(1));
const vertices: vertexset{Vertex};
const lvl: vector{Vertex}(int);
const acc: vector{Vertex}(int);
func init(v: Vertex)
    lvl[v] = 0;
end
func relax(src: Vertex, dst: Vertex)
    if (lvl[src] == 1)
        acc[dst] min= lvl[src];
    end
end
func main()
    vertices.init(init);
    var stuck: int = 1;
    while (stuck > 0)
        edges.process(relax);
    end
end
"""


def test_nontermination_heuristics_fire():
    res = analysis.analyze(NONTERM_GT)
    codes = res.codes()
    assert "GT401" in codes  # `stuck` never written in the body
    assert "GT402" in codes  # frontier props never updated in the loop
    gt401 = [d for d in res.diagnostics if d.code == "GT401"]
    assert "stuck" in gt401[0].message


def test_frontier_loops_of_builtins_are_quiet():
    for name in ("BFS_ECP", "BFS_HYBRID", "SSSP", "KCORE"):
        res = analysis.analyze(getattr(sources, name))
        assert "GT401" not in res.codes(), name
        assert "GT402" not in res.codes(), name


def test_uninit_read_and_dead_write():
    src = """
element Vertex end
const edges: edgeset{Vertex}(Vertex, Vertex) = load(argv(1));
const vertices: vertexset{Vertex};
const seen: vector{Vertex}(int);
const orphan: vector{Vertex}(int);
func touch(v: Vertex)
    orphan[v] = seen[v] + 1;
end
func main()
    vertices.process(touch);
end
"""
    res = analysis.analyze(src)
    by_code = {d.code: d for d in res.diagnostics}
    assert "GT301" in by_code and by_code["GT301"].prop == "seen"
    assert "GT302" in by_code and by_code["GT302"].prop == "orphan"


def test_shape_overflow_analyses():
    small = GraphShape(n_vertices=100, n_edges=1000)
    res = analysis.analyze(sources.PAGERANK, shape=small)
    assert "GT501" not in res.codes() and "GT502" not in res.codes()

    big = GraphShape(n_vertices=100, n_edges=2**31 - 1)
    res = analysis.analyze(sources.KCORE, shape=big)
    assert "GT501" in res.codes()  # int accumulators at |E| scale
    assert "GT502" not in res.codes()  # |E| still fits int32

    huge = GraphShape(n_vertices=100, n_edges=2**31)
    res = analysis.analyze(sources.KCORE, shape=huge)
    assert "GT502" in res.codes()
    assert not res.ok  # GT502 is error-level


def test_conflicting_reduce_ops_gt102():
    src = """
element Vertex end
const edges: edgeset{Vertex}(Vertex, Vertex) = load(argv(1));
const vertices: vertexset{Vertex};
const P: vector{Vertex}(int);
func initP(v: Vertex)
    P[v] = 0;
end
func upd(src: Vertex, dst: Vertex)
    P[dst] += 1;
    P[dst] min= src;
end
func main()
    vertices.init(initP);
    edges.process(upd);
end
"""
    res = analysis.analyze(src)
    assert "GT102" in res.codes()
    assert res.certificate == analysis.RACY


# ---------------------------------------------------------------------------
# accelerator surfaces
# ---------------------------------------------------------------------------


def test_accelerator_report_and_manifest_carry_certificate(tmp_path):
    prog = repro.compile(sources.PAGERANK)
    acc = prog.lower(repro.Target(),
                     shape=GraphShape(n_vertices=4, n_edges=4))
    rep = acc.report()
    assert rep.determinism == analysis.REDUCTION_DETERMINISTIC
    assert "determinism: reduction-deterministic" in rep.describe()

    path = acc.save(str(tmp_path / "pr"))
    manifests = [f for f in os.listdir(path) if f.endswith(".json")]
    with open(os.path.join(path, manifests[0])) as f:
        manifest = json.load(f)
    assert manifest["determinism"] == analysis.REDUCTION_DETERMINISTIC


# ---------------------------------------------------------------------------
# the lint CLI
# ---------------------------------------------------------------------------


def test_lint_cli_clean_and_racy(tmp_path, capsys):
    from repro.lint import main

    good = tmp_path / "good.gt"
    good.write_text(sources.BFS_ECP)
    racy = tmp_path / "racy.gt"
    racy.write_text(RACY_GT)

    assert main([str(good)]) == 0
    assert main([str(good), str(racy)]) == 1
    out = capsys.readouterr().out
    assert "GT101" in out

    assert main(["--json", str(racy)]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is False
    (target,) = doc["targets"].values()
    assert target["certificate"] == analysis.RACY
    assert any(d["code"] == "GT101" for d in target["diagnostics"])


def test_lint_cli_builtins_clean(capsys):
    from repro.lint import main

    assert main(["--json", "--builtins"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is True
    # 8 text algorithms + the embedded twins
    assert len(doc["targets"]) >= 10


def test_lint_cli_module_spec(capsys):
    from repro.lint import main

    assert main(["repro.algorithms.sources:WCC"]) == 0
    assert main(["tests.test_analysis:RACY_GT"]) == 1
