"""Serving tier: artifact registry + async scheduler behind repro.serve().

Acceptance criteria of the serving PR:

* ``repro.serve()`` / ``GraphService.submit`` pick the cheapest execution
  path automatically — already-resident session, warm on-disk artifact,
  or cold compile — observable via ``EngineStats.compile_time_s == 0`` on
  warm paths and the registry hit counters;
* registry concurrency: parallel submits for one fingerprint perform
  exactly ONE lowering (single-flight); eviction under a size-1 registry
  never tears down an entry a query still pins; stale-fingerprint
  artifacts are quarantined (renamed aside + negative entry), not
  re-probed on every miss;
* the scheduler sheds load with typed :class:`Overloaded`, fails expired
  queued requests with :class:`DeadlineExceeded`, and serves weighted
  tenants proportionally;
* every closed serving surface (SessionPool, DynamicBatcher, scheduler,
  GraphService) rejects submissions with typed :class:`ServiceClosed`;
* ``repro.run`` one-shots route through the same selection (second call
  pays zero compile time), and ``make_warm_runner`` + the CompileOptions
  legacy-kwargs shim emit DeprecationWarnings naming the replacement.
"""
import json
import os
import threading
import time
import warnings

import numpy as np
import pytest

import repro
from repro.algorithms import sources
from repro.batch.dynamic import DynamicBatcher
from repro.core import CompileOptions, ServiceClosed, Target
from repro.core.accelerator import GraphShape
from repro.core.program import compile_program
from repro.graph import generators
from repro.graph.storage import GraphDelta
from repro.serving import (
    ArtifactRegistry,
    DeadlineExceeded,
    GraphService,
    Overloaded,
    RequestScheduler,
    reset_default_service,
)
from repro.serving.metrics import LatencyHistogram


@pytest.fixture
def graph():
    return generators.uniform_random(200, 1200, seed=3)


@pytest.fixture
def bfs():
    return compile_program(sources.BFS_ECP)


# ---------------------------------------------------------------------------
# registry: single-flight, eviction, quarantine
# ---------------------------------------------------------------------------


def test_parallel_acquire_single_flight(graph, bfs):
    reg = ArtifactRegistry(None, max_resident=4)
    target = Target()
    entries, errors = [], []

    def worker():
        try:
            e = reg.acquire(bfs, graph, target)
            entries.append(e)
            e.release()
        except BaseException as exc:  # pragma: no cover - diagnostic
            errors.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert reg.lowerings == 1  # one compile served all 8
    assert len({id(e) for e in entries}) == 1
    reg.close()


def test_parallel_service_submit_single_flight(graph, bfs, tmp_path):
    # max_batch=1 so the 8 submits dispatch as 8 concurrent executions
    # racing registry.acquire — still exactly one lowering
    with repro.serve(str(tmp_path), workers=4, max_batch=1) as svc:
        futs = [svc.submit(bfs, graph, root=r) for r in range(8)]
        levels = [f.result().properties["old_level"] for f in futs]
        assert svc.registry.lowerings == 1
    seq = compile_program(sources.BFS_ECP).bind(graph)
    for r, lvl in enumerate(levels):
        np.testing.assert_array_equal(
            np.asarray(lvl), np.asarray(seq.run(root=r).properties["old_level"])
        )


def test_size1_eviction_keeps_inflight_safe(graph):
    # two programs ping-pong through a size-1 registry: constant eviction
    # churn while queries are in flight on both entries
    with repro.serve(False, workers=2, max_batch=1, max_resident=1) as svc:
        futs = []
        for i in range(6):
            futs.append(svc.submit("bfs", graph, root=i))
            futs.append(svc.submit("pagerank", graph, iters=5 + i))
        results = [f.result() for f in futs]
        assert all(r is not None for r in results)
        stats = svc.stats()
        assert stats["queries"]["errors"] == 0
        assert stats["queries"]["completed"] == 12
        assert stats["registry"]["evictions"] >= 1
        assert stats["registry"]["resident"] <= 1


def test_stale_artifact_quarantined_not_retried(graph, bfs, tmp_path):
    store = str(tmp_path)
    target = Target()
    shape = GraphShape.of(graph)
    from repro.core.accelerator import accelerator_fingerprint

    key = accelerator_fingerprint(bfs.fingerprint, target, shape)
    path = os.path.join(store, key[:24])

    reg = ArtifactRegistry(store)
    reg.acquire(bfs, graph, target).release()
    reg.close()
    assert os.path.isdir(path)

    # tamper: the stored source no longer matches the fingerprint
    with open(os.path.join(path, "program.gt"), "a") as f:
        f.write("\n// drift\n")

    reg2 = ArtifactRegistry(store)
    entry = reg2.acquire(bfs, graph, target)
    entry.release()
    snap = reg2.metrics.snapshot()["registry"]
    assert snap["quarantined"] == 1
    assert snap["artifact_hits"] == 0
    assert reg2.lowerings == 1  # cold compile, not a retry loop
    assert os.path.isdir(path + ".quarantined")  # bytes kept for postmortem
    reg2.close()

    # the fresh save healed the store: a third process warm-starts
    reg3 = ArtifactRegistry(store)
    reg3.acquire(bfs, graph, target).release()
    snap3 = reg3.metrics.snapshot()["registry"]
    assert snap3["artifact_hits"] == 1
    assert reg3.lowerings == 0
    reg3.close()


# ---------------------------------------------------------------------------
# scheduler: admission control, deadlines, fairness
# ---------------------------------------------------------------------------


def _blocking_execute(started, release):
    def execute(job, param_sets):
        started.set()
        assert release.wait(timeout=30)
        return [dict(p) for p in param_sets]

    return execute


def test_overloaded_typed_rejection():
    started, release = threading.Event(), threading.Event()
    sched = RequestScheduler(
        _blocking_execute(started, release),
        workers=1, max_batch=1, max_queue=2, max_wait_s=0.0,
    )
    try:
        f0 = sched.submit("job", {"i": 0}, group_key="g")
        assert started.wait(timeout=10)  # worker is now occupied
        f1 = sched.submit("job", {"i": 1}, group_key="g")
        f2 = sched.submit("job", {"i": 2}, group_key="g")
        with pytest.raises(Overloaded):
            sched.submit("job", {"i": 3}, group_key="g")
        snap = sched.metrics.snapshot()
        assert snap["queries"]["rejected_overloaded"] == 1
        release.set()
        assert f0.result(timeout=10)["i"] == 0
        assert f1.result(timeout=10)["i"] == 1
        assert f2.result(timeout=10)["i"] == 2
    finally:
        release.set()
        sched.close()


def test_deadline_exceeded_in_queue():
    started, release = threading.Event(), threading.Event()
    sched = RequestScheduler(
        _blocking_execute(started, release),
        workers=1, max_batch=1, max_queue=8, max_wait_s=0.0,
    )
    try:
        f0 = sched.submit("job", {"i": 0}, group_key="g")
        assert started.wait(timeout=10)
        f1 = sched.submit("job", {"i": 1}, group_key="g", deadline_s=0.05)
        with pytest.raises(DeadlineExceeded):
            f1.result(timeout=10)  # failed on time, without an exec slot
        release.set()
        assert f0.result(timeout=10)["i"] == 0
        snap = sched.metrics.snapshot()
        assert snap["queries"]["rejected_deadline"] == 1
        assert snap["queries"]["completed"] == 1
    finally:
        release.set()
        sched.close()


def test_weighted_tenant_fairness():
    started, release = threading.Event(), threading.Event()
    order = []
    lock = threading.Lock()

    def execute(job, param_sets):
        if job == "plug":
            started.set()
            assert release.wait(timeout=30)
        else:
            with lock:
                order.extend(p["tenant"] for p in param_sets)
        return [dict(p) for p in param_sets]

    sched = RequestScheduler(
        execute, workers=1, max_batch=1, max_queue=64, max_wait_s=0.0,
        tenant_weights={"heavy": 3.0, "light": 1.0},
    )
    try:
        plug = sched.submit("plug", {}, group_key="plug", tenant="warm")
        assert started.wait(timeout=10)
        futs = []
        # both queues full before the worker frees up
        for _ in range(8):
            futs.append(sched.submit(
                "q", {"tenant": "light"}, group_key="l", tenant="light"))
        for _ in range(8):
            futs.append(sched.submit(
                "q", {"tenant": "heavy"}, group_key="h", tenant="heavy"))
        release.set()
        plug.result(timeout=10)
        for f in futs:
            f.result(timeout=30)
        first8 = order[:8]
        # served/weight argmin: the weight-3 tenant gets ~3x the early slots
        assert first8.count("heavy") >= 2 * first8.count("light")
    finally:
        release.set()
        sched.close()


def test_deadline_caps_batch_fill_wait():
    # a forming batch must not wait out max_wait_s when its head's
    # deadline is nearer: the fill window is capped by the deadline
    done = threading.Event()

    def execute(job, param_sets):
        done.set()
        return [dict(p) for p in param_sets]

    sched = RequestScheduler(
        execute, workers=1, max_batch=8, max_queue=8, max_wait_s=5.0,
    )
    try:
        t0 = time.monotonic()
        f = sched.submit("job", {"i": 0}, group_key="g", deadline_s=0.1)
        assert f.result(timeout=10)["i"] == 0
        assert time.monotonic() - t0 < 2.0  # not the 5s straggler window
    finally:
        sched.close()


# ---------------------------------------------------------------------------
# ServiceClosed: typed rejection from every closed surface
# ---------------------------------------------------------------------------


def test_service_closed_everywhere(graph, bfs):
    pool = bfs.pool(graph, size=1)
    pool.close()
    with pytest.raises(ServiceClosed):
        pool.submit(root=0)
    with pytest.raises(ServiceClosed):
        pool.warmup(root=0)
    with pytest.raises(ServiceClosed):
        pool.run_batch([{"root": 0}])
    with pytest.raises(ServiceClosed):
        pool.refresh_graph()

    batcher = DynamicBatcher(lambda ps: ps, max_batch=2)
    batcher.close()
    with pytest.raises(ServiceClosed):
        batcher.submit({"root": 0})

    sched = RequestScheduler(lambda job, ps: ps, workers=1)
    sched.close()
    with pytest.raises(ServiceClosed):
        sched.submit("job", {}, group_key="g")

    svc = GraphService(False, workers=1)
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit("bfs", graph, root=0)
    with pytest.raises(ServiceClosed):
        svc.update("bfs", graph, GraphDelta())

    # ServiceClosed stays a SessionError: pre-typed handlers keep working
    from repro.core import SessionError

    assert issubclass(ServiceClosed, SessionError)


# ---------------------------------------------------------------------------
# warm-path selection through the public surface
# ---------------------------------------------------------------------------


def test_submit_picks_resident_session(graph, tmp_path):
    with repro.serve(str(tmp_path), workers=1, max_batch=1) as svc:
        first = svc.run("bfs", graph, root=0)
        warm = svc.run("bfs", graph, root=1)
        assert first.stats.compile_time_s > 0  # cold lowering
        assert warm.stats.compile_time_s == 0.0  # resident session reuse
        reg = svc.stats()["registry"]
        assert reg["cold_lowerings"] == 1
        assert reg["resident_hits"] >= 1


def test_cross_service_warm_artifact(graph, tmp_path):
    with repro.serve(str(tmp_path), workers=1, max_batch=1) as svc:
        svc.run("bfs", graph, root=0)
        assert svc.stats()["registry"]["cold_lowerings"] == 1
    # a new service (fresh process stand-in) warm-starts from the store:
    # zero lowerings, and resident reruns stay compile-free
    with repro.serve(str(tmp_path), workers=1, max_batch=1) as svc2:
        svc2.run("bfs", graph, root=0)
        warm = svc2.run("bfs", graph, root=1)
        reg = svc2.stats()["registry"]
        assert reg["artifact_hits"] == 1
        assert reg["cold_lowerings"] == 0
        assert svc2.registry.lowerings == 0
        assert warm.stats.compile_time_s == 0.0


def test_run_one_shot_routes_through_default_service(graph, tmp_path,
                                                    monkeypatch):
    monkeypatch.setenv("REPRO_ARTIFACT_DIR", str(tmp_path))
    reset_default_service()
    try:
        first = repro.run("bfs", graph, root=0)
        again = repro.run("bfs", graph, root=0)
        assert (np.asarray(first.properties["old_level"])
                == np.asarray(again.properties["old_level"])).all()
        assert again.stats.compile_time_s == 0.0  # resident reuse
        from repro.serving.service import default_service

        assert default_service().registry.lowerings == 1
    finally:
        reset_default_service()


def test_named_source_and_program_inputs_share_entry(graph):
    # "bfs", the raw source text, and the compiled Program all resolve to
    # one fingerprint — one lowering serves all three input styles
    with repro.serve(False, workers=1, max_batch=1) as svc:
        svc.run("bfs", graph, root=0)
        svc.run(sources.BFS_ECP, graph, root=1)
        svc.run(compile_program(sources.BFS_ECP), graph, root=2)
        assert svc.registry.lowerings == 1
        assert svc.stats()["registry"]["resident_hits"] == 2


def test_submit_validates_params_on_caller(graph):
    with repro.serve(False, workers=1) as svc:
        with pytest.raises(repro.ProgramError):
            svc.submit("bfs", graph, rooot=3)
        with pytest.raises(repro.ProgramError):
            svc.submit("this is not a .gt program", graph)
    from repro.serving import NAMED_ALGORITHMS

    with pytest.raises(KeyError):
        NAMED_ALGORITHMS["not_an_algorithm_name"]


# ---------------------------------------------------------------------------
# streaming updates through the service (versioned graphs as tenants)
# ---------------------------------------------------------------------------


def test_service_update_bumps_version_in_place():
    base = generators.uniform_random(300, 1800, seed=5)
    shape = GraphShape.bucket_for(base.n_vertices, base.n_edges)
    g = base.pad_to(shape.n_vertices, shape.n_edges)
    rng = np.random.default_rng(7)
    with repro.serve(False, workers=1, max_batch=1) as svc:
        r0 = svc.run("bfs", g, root=0, tenant="v0")
        assert r0.version == 0
        edges = rng.integers(0, base.n_vertices, size=(16, 2)).astype(np.int32)
        v = svc.update("bfs", g, GraphDelta(added_edges=edges))
        assert v == 1
        r1 = svc.run("bfs", g, root=0, tenant="v1")
        assert r1.version == 1
        # in-bucket update: refresh is shape-check-only, no re-lowering
        assert r1.stats.compile_time_s == 0.0
        assert svc.registry.lowerings == 1
        # results match a fresh bind of the updated graph
        fresh = compile_program(sources.BFS_ECP).bind(g).run(root=0)
        np.testing.assert_array_equal(
            np.asarray(r1.properties["old_level"]),
            np.asarray(fresh.properties["old_level"]),
        )


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for ms in range(1, 101):  # 1ms..100ms uniform
        h.record(ms / 1e3)
    snap = h.snapshot()
    assert snap["count"] == 100
    # bucketed upper bounds: within one geometric step of the true value
    assert 0.045 <= snap["p50_ms"] / 1e3 <= 0.075
    assert 0.09 <= snap["p99_ms"] / 1e3 <= 0.15
    assert snap["max_ms"] == 100.0
    assert LatencyHistogram().snapshot()["p99_ms"] == 0.0


def test_stats_snapshot_is_json_per_tenant(graph):
    with repro.serve(False, workers=2, max_batch=4,
                     tenant_weights={"a": 1.0, "b": 2.0}) as svc:
        futs = [svc.submit("bfs", graph, root=i, tenant="a",
                           deadline_s=60.0) for i in range(3)]
        futs += [svc.submit("bfs", graph, root=i, tenant="b")
                 for i in range(2)]
        for f in futs:
            f.result()
        snap = svc.stats()
    encoded = json.loads(json.dumps(snap))  # JSON-serializable end to end
    assert encoded["queries"]["submitted"] == 5
    assert encoded["queries"]["completed"] == 5
    assert encoded["queries"]["deadline_misses"] == 0
    assert encoded["tenants"]["a"]["submitted"] == 3
    assert encoded["tenants"]["b"]["submitted"] == 2
    assert encoded["programs"]["bfs"]["completed"] == 5
    assert encoded["tenants"]["a"]["latency_ms"]["p99_ms"] > 0
    assert encoded["batches"]["queries"] == 5
    assert 0 < encoded["batches"]["occupancy"] <= 1
    assert encoded["queue_depth"] == 0
    assert encoded["uptime_s"] >= 0


# ---------------------------------------------------------------------------
# deprecations (the api_redesign satellites)
# ---------------------------------------------------------------------------


def test_make_warm_runner_deprecated(graph):
    from repro.algorithms.runners import make_warm_runner

    with pytest.warns(DeprecationWarning, match="repro.run"):
        run = make_warm_runner(sources.BFS_ECP, graph, None, {"root": 0})
    assert run().properties["old_level"] is not None


def test_compile_options_legacy_kwargs_deprecated():
    with pytest.warns(DeprecationWarning) as rec:
        opts = CompileOptions(burst=False, pallas=True)
    # the message names the exact Target(...) replacement
    assert "Target(burst=False, pallas=True)" in str(rec[0].message)
    assert opts.burst is False and opts.pallas is True

    # the new-style spellings stay silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        CompileOptions()
        CompileOptions(passes="none")
        CompileOptions.baseline()
        CompileOptions.with_only("burst")
        CompileOptions.full(pallas=True)
        CompileOptions(target_overrides=(("burst", False),))
