"""Roofline pipeline tests: HLO collective parser, term math, and the
dry-run artifact grid (deliverables e/g)."""
import json
from pathlib import Path

import pytest

from repro.launch.dryrun import parse_collectives, input_specs, _micro_batches
from repro.configs import ARCH_IDS, SHAPES, get_config, shape_supported, cells

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


# --------------------------------------------------------------------------
# collective parser (unit, on synthetic HLO text)
# --------------------------------------------------------------------------

HLO = """
HloModule jit_step
%fused (p0: f32[128,256]) -> f32[128,256] {
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p0), replica_groups={{0,1}}
  %ag = f32[256,256]{1,0} all-gather(f32[128,256]{1,0} %ar), dimensions={0}
  %rs = f32[64,256]{1,0} reduce-scatter(f32[128,256]{1,0} %ag2), dimensions={0}
  %a2a = f32[128,256]{1,0} all-to-all(f32[128,256]{1,0} %x), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(f32[128,256]{1,0} %y)
  %ars = f32[128,256]{1,0} all-reduce-start(f32[128,256]{1,0} %z)
  %ard = f32[128,256]{1,0} all-reduce-done(f32[128,256]{1,0} %ars)
  %not_a_collective = f32[999,999]{1,0} add(f32[999,999] %a, f32[999,999] %b)
}
"""


def test_parse_collectives_counts_and_bytes():
    res = parse_collectives(HLO)
    c = res["counts"]
    assert c["all-reduce"] == 2  # plain + -start; -done skipped
    assert c["all-gather"] == 1
    assert c["reduce-scatter"] == 1
    assert c["all-to-all"] == 1
    assert c["collective-permute"] == 1
    b = res["wire_bytes"]
    t = 128 * 256 * 4
    assert b["all-reduce"] == 2 * 2 * t  # 2x ring factor, two ops
    assert b["all-gather"] == 2 * t  # result buffer (256,256)
    assert b["reduce-scatter"] == t  # operand buffer
    assert b["all-to-all"] == t
    assert b["collective-permute"] == t
    # the add must not be counted
    assert res["total_wire_bytes"] < 10 * 2 * t


def test_parse_collectives_empty():
    assert parse_collectives("HloModule empty")["total_wire_bytes"] == 0


# --------------------------------------------------------------------------
# input specs / microbatching
# --------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    ok, _ = shape_supported(cfg, shape)
    if not ok:
        pytest.skip("unsupported cell")
    spec = input_specs(cfg, SHAPES[shape])
    s = SHAPES[shape]
    key = "embeds" if cfg.frontend != "none" else "tokens"
    assert key in spec
    lead = spec[key].shape
    assert lead[0] == s.global_batch
    assert lead[1] == (1 if s.kind == "decode" else s.seq_len)


def test_microbatches_divisibility():
    cfg = get_config("granite-20b")
    for shards in (16, 32):
        m = _micro_batches(cfg, SHAPES["train_4k"], shards)
        b = SHAPES["train_4k"].global_batch
        assert b % m == 0 and (b // m) % shards == 0


# --------------------------------------------------------------------------
# roofline math
# --------------------------------------------------------------------------


def test_model_flops_definitions():
    from benchmarks.roofline_report import model_flops

    dense = get_config("granite-20b")
    moe = get_config("kimi-k2-1t-a32b")
    tr = SHAPES["train_4k"]
    assert model_flops(dense, tr) == pytest.approx(
        6 * dense.param_count() * tr.global_batch * tr.seq_len, rel=1e-6
    )
    # MoE uses ACTIVE params
    assert model_flops(moe, tr) == pytest.approx(
        6 * moe.active_param_count() * tr.global_batch * tr.seq_len, rel=1e-6
    )
    dec = SHAPES["decode_32k"]
    assert model_flops(dense, dec) == pytest.approx(
        2 * dense.param_count() * dec.global_batch, rel=1e-6
    )


def test_roofline_terms_from_artifact():
    from benchmarks.roofline_report import analyze_cell, PEAK, HBM, LINK

    fake = {
        "arch": "qwen3-0.6b",
        "shape": "train_4k",
        "mesh": "single",
        "n_devices": 256,
        "roofline_raw": {"flops": 1e14, "bytes": 1e12, "wire_bytes": 1e10},
    }
    r = analyze_cell(fake)
    assert r["compute_s"] == pytest.approx(1e14 / PEAK)
    assert r["memory_s"] == pytest.approx(1e12 / HBM)
    assert r["collective_s"] == pytest.approx(1e10 / LINK)
    assert r["dominant"] == "memory"
    assert 0 < r["roofline_frac"] < 1


# --------------------------------------------------------------------------
# the artifact grid itself (deliverable e: 32 cells x 2 meshes, all ok)
# --------------------------------------------------------------------------


def test_dryrun_grid_complete_and_green():
    if not ART.exists():
        pytest.skip("dry-run artifacts not generated in this checkout")
    expected = {(a, s) for a, s, ok, _ in cells() if ok}
    for mesh in ("single", "multi"):
        seen = set()
        for f in ART.glob(f"*__{mesh}.json"):
            d = json.loads(f.read_text())
            assert d.get("ok"), f"{f.name}: {d.get('error')}"
            assert "gate" in d and "roofline_raw" in d
            seen.add((d["arch"], d["shape"]))
        missing = expected - seen
        assert not missing, f"mesh={mesh} missing cells: {missing}"


def test_skip_reasons_documented():
    skipped = [(a, s, why) for a, s, ok, why in cells() if not ok]
    assert len(skipped) == 8  # 2 hubert decode shapes + 6 full-attn long_500k
    assert all(why for _, _, why in skipped)
