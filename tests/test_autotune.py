"""repro.autotune: profile-guided Target search, persisted and reused.

Covers the tentpole contracts:

* analysis-pruned candidate enumeration — a GT101-racy program can never
  search ``shuffle=False`` (the engine forces shuffle back on, so those
  candidates are dead duplicates), and a pipeline whose only frontier
  kernel carries a DENSE verdict skips ``compact_frontier`` variants;
* the TuningCache round-trips configs through per-key JSON files,
  tolerates corrupt/foreign files as misses, and a *fresh* cache
  instance over the same store (the fresh-process analogue) resolves a
  persisted config with **zero** search trials;
* TunedConfig survives to_dict/from_dict with an identical Target — same
  hash, same equality, same ``accelerator_fingerprint`` — including when
  the Target is rebuilt through the CompileOptions ``target_overrides``
  compat shim and through legacy substrate kwargs (DeprecationWarning
  path): tuned configs must rehydrate to identical fingerprint keys;
* ``program.lower(..., tuned=True)`` is a pure lookup that stamps the
  config into the Accelerator (and its saved manifest), and the serving
  tier resolves tuned Targets on submission (``tuned_hits`` in stats);
* ``accelerator.report()`` degrades gracefully when XLA cost analysis is
  unavailable — explicit ``None`` estimates, never an exception — and
  the tuner's cost model tolerates those ``None`` s.
"""
from __future__ import annotations

import json
import os
import warnings

import pytest

import repro
from repro.autotune import (
    AutoTuner,
    TunedConfig,
    TuningCache,
    autotune,
    program_mir_fingerprint,
    shape_bucket,
    tuning_dir_for,
    tuning_key,
)
from repro.core.accelerator import (
    GraphShape,
    accelerator_fingerprint,
    load_accelerator,
)
from repro.core.options import CompileOptions
from repro.core.target import Target
from repro.graph import generators

RACY_GT = """
element Vertex end
const edges: edgeset{Vertex}(Vertex, Vertex) = load(argv(1));
const vertices: vertexset{Vertex};
const P: vector{Vertex}(int);
func initP(v: Vertex)
    P[v] = 0;
end
func upd(src: Vertex, dst: Vertex)
    P[dst] = P[src] + 1;
end
func main()
    vertices.init(initP);
    edges.process(upd);
end
"""


@pytest.fixture
def graph():
    return generators.power_law(400, 2400, seed=0)


@pytest.fixture
def bfs_program():
    from repro.algorithms import sources

    return repro.compile(sources.BFS_ECP)


# --------------------------------------------------------------------------
# analysis-pruned candidate enumeration
# --------------------------------------------------------------------------


def test_candidates_enumerate_boolean_knob_grid(bfs_program):
    base = bfs_program.options.resolve_target()
    cands, pruned = AutoTuner(TuningCache()).candidates(bfs_program, base)
    # BFS: non-racy, frontier-relevant, has edge kernels -> full 2^4 grid
    assert len(cands) == 16
    assert pruned == []
    # the grid never touches the pallas routing axis
    assert all(t.pallas == base.pallas for t in cands)
    assert len(set(cands)) == len(cands)


def test_racy_program_pins_shuffle_on():
    program = repro.compile(RACY_GT)
    base = program.options.resolve_target()
    cands, pruned = AutoTuner(TuningCache()).candidates(program, base)
    assert all(t.shuffle for t in cands), \
        "racy programs must never search shuffle=False (engine forces it)"
    assert any("shuffle pinned on" in p for p in pruned)
    assert len(cands) < 16


def test_dense_only_program_skips_compact_frontier_variants():
    from repro.algorithms import sources

    program = repro.compile(sources.PAGERANK)
    base = program.options.resolve_target()
    cands, pruned = AutoTuner(TuningCache()).candidates(program, base)
    if any("compact_frontier variants skipped" in p for p in pruned):
        assert all(
            t.compact_frontier == base.compact_frontier for t in cands
        )
    else:  # pagerank grew a sparse frontier kernel: grid must include both
        assert {t.compact_frontier for t in cands} == {True, False}


# --------------------------------------------------------------------------
# TuningCache persistence
# --------------------------------------------------------------------------


def _mk_config(mir_fp="a" * 64, target=None, bucket=None) -> TunedConfig:
    return TunedConfig(
        mir_fingerprint=mir_fp,
        bucket=bucket or GraphShape.bucket_for(400, 2400, weighted=False),
        target=target or Target(),
        objective_s=0.010,
        baseline_s=0.025,
        trials=5,
    )


def test_cache_memory_roundtrip():
    cache = TuningCache()
    cfg = _mk_config()
    cache.put(cfg)
    got = cache.get(cfg.mir_fingerprint, cfg.bucket, cfg.target.kind)
    assert got == cfg
    assert cache.stats()["hits"] == 1
    assert cache.get("b" * 64, cfg.bucket) is None
    assert cache.stats()["misses"] == 1


def test_cache_disk_roundtrip_fresh_instance(tmp_path):
    store = str(tmp_path / "tuning")
    cfg = _mk_config()
    TuningCache(store).put(cfg)
    fresh = TuningCache(store)  # fresh process analogue: empty memory
    got = fresh.get(cfg.mir_fingerprint, cfg.bucket, cfg.target.kind)
    assert got == cfg
    assert got.target is not cfg.target  # rebuilt from JSON, equal by value
    assert fresh.stats() == {"entries": 1, "hits": 1, "misses": 0,
                             "stores": 0}


def test_cache_corrupt_file_is_a_miss_not_a_crash(tmp_path):
    store = str(tmp_path / "tuning")
    cfg = _mk_config()
    cache = TuningCache(store)
    cache.put(cfg)
    path = cache._path(cfg.key)
    with open(path, "w") as f:
        f.write("{not json")
    fresh = TuningCache(store)
    assert fresh.get(cfg.mir_fingerprint, cfg.bucket, cfg.target.kind) is None
    # a re-search overwrites the corrupt entry
    fresh.put(cfg)
    assert TuningCache(store).get(
        cfg.mir_fingerprint, cfg.bucket, cfg.target.kind
    ) == cfg


def test_cache_foreign_file_content_mismatch_is_a_miss(tmp_path):
    store = str(tmp_path / "tuning")
    cfg = _mk_config()
    cache = TuningCache(store)
    cache.put(cfg)
    other_key = tuning_key("c" * 64, cfg.bucket, cfg.target.kind)
    os.replace(cache._path(cfg.key), cache._path(other_key))
    fresh = TuningCache(store)
    assert fresh.get("c" * 64, cfg.bucket, cfg.target.kind) is None


# --------------------------------------------------------------------------
# TunedConfig / Target identity round trips (fingerprint stability)
# --------------------------------------------------------------------------


def test_tuned_config_dict_roundtrip_preserves_target_identity():
    cfg = _mk_config(target=Target(burst=False, shuffle=False))
    back = TunedConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg
    assert back.target == cfg.target
    assert hash(back.target) == hash(cfg.target)
    assert back.key == cfg.key
    # the identity that matters downstream: same artifact fingerprint
    shape = GraphShape(n_vertices=512, n_edges=4096, weighted=False)
    assert accelerator_fingerprint("f" * 64, back.target, shape) == \
        accelerator_fingerprint("f" * 64, cfg.target, shape)


def test_target_roundtrip_through_target_overrides_shim():
    """A tuned Target rebuilt via CompileOptions(target_overrides=...)
    must rehydrate to the identical fingerprint key (satellite: hash/eq
    round-trip through the compat shim)."""
    tuned = Target(burst=True, cache=False, shuffle=True,
                   compact_frontier=False)
    overrides = tuple(sorted(
        (k, v) for k, v in tuned.to_dict().items()
        if getattr(Target(), k) != v
    ))
    opts = CompileOptions(target_overrides=overrides)
    rebuilt = opts.resolve_target()
    assert rebuilt == tuned
    assert hash(rebuilt) == hash(tuned)
    shape = GraphShape(n_vertices=512, n_edges=4096, weighted=False)
    assert accelerator_fingerprint("f" * 64, rebuilt, shape) == \
        accelerator_fingerprint("f" * 64, tuned, shape)


def test_target_roundtrip_through_legacy_kwargs_shim():
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # anything but the deprecation fails
        with pytest.warns(DeprecationWarning):
            opts = CompileOptions(cache=False, shuffle=False)
    rebuilt = opts.resolve_target()
    direct = Target(cache=False, shuffle=False)
    assert rebuilt == direct
    assert hash(rebuilt) == hash(direct)


def test_mir_fingerprint_is_options_independent(graph):
    from repro.algorithms import sources

    a = repro.compile(sources.BFS_ECP)
    b = repro.compile(sources.BFS_ECP, CompileOptions(
        target_overrides=(("burst", False),)
    ))
    assert a.fingerprint != b.fingerprint or a is b  # program cache key
    assert program_mir_fingerprint(a) == program_mir_fingerprint(b)


def test_shape_bucket_is_padding_invariant(graph):
    bucket = shape_bucket(graph=graph)
    padded = graph.pad_to(bucket.n_vertices, bucket.n_edges)
    assert shape_bucket(graph=padded) == bucket


# --------------------------------------------------------------------------
# the search end to end
# --------------------------------------------------------------------------


def test_tune_searches_then_fresh_cache_reuses_with_zero_trials(
        bfs_program, graph, tmp_path):
    store = tuning_dir_for(str(tmp_path))
    tuner = AutoTuner(TuningCache(store), reps=1, max_candidates=3)
    report = tuner.tune(bfs_program, graph, params={"root": 0})
    assert not report.cache_hit
    assert report.trials >= 2  # base + at least the baseline referee
    assert report.config.objective_s > 0
    # tuned is never slower than the measured baseline referee
    assert report.config.objective_s <= report.config.baseline_s * 1.0001
    assert report.accelerator is not None
    assert report.accelerator.tuned == report.config.to_dict()

    fresh = AutoTuner(TuningCache(store))
    warm = fresh.tune(bfs_program, graph, params={"root": 0})
    assert warm.cache_hit
    assert warm.trials == 0
    assert warm.config == report.config
    assert fresh.cache.hits >= 1


def test_autotune_convenience_and_force(bfs_program, graph, tmp_path):
    cache = TuningCache(tuning_dir_for(str(tmp_path)))
    first = autotune(bfs_program, graph, params={"root": 0}, cache=cache,
                     reps=1, max_candidates=2)
    again = autotune(bfs_program, graph, params={"root": 0}, cache=cache)
    assert again.cache_hit and again.trials == 0
    forced = autotune(bfs_program, graph, params={"root": 0}, cache=cache,
                      reps=1, max_candidates=2, force=True)
    assert not forced.cache_hit and forced.trials >= 2
    assert first.config.key == forced.config.key


def test_lower_tuned_true_is_pure_lookup_and_stamps_manifest(
        bfs_program, graph, tmp_path):
    cache = TuningCache(tuning_dir_for(str(tmp_path)))
    tuned_target = Target(cache=False, shuffle=False)
    cache.put(TunedConfig(
        mir_fingerprint=program_mir_fingerprint(bfs_program),
        bucket=shape_bucket(graph=graph),
        target=tuned_target,
        objective_s=0.001, baseline_s=0.002, trials=3,
    ))
    acc = bfs_program.lower(graph=graph, tuned=True, tuning_cache=cache)
    assert acc.target == tuned_target
    assert acc.tuned is not None
    assert Target.from_dict(acc.tuned["target"]) == tuned_target
    # miss -> default target, no stamp
    other = generators.power_law(5000, 60000, seed=1)
    acc_miss = bfs_program.lower(graph=other, tuned=True, tuning_cache=cache)
    assert acc_miss.tuned is None
    assert acc_miss.target == bfs_program.options.resolve_target()

    # the stamp survives save -> load (manifest round trip)
    art = acc.save(str(tmp_path / "art"))
    loaded = load_accelerator(art)
    assert loaded.tuned == acc.tuned
    assert loaded.target == tuned_target


def test_serving_resolves_tuned_target_and_counts_hits(
        bfs_program, graph, tmp_path):
    store = str(tmp_path / "registry")
    tuned_target = Target(shuffle=False, compact_frontier=False)
    TuningCache(tuning_dir_for(store)).put(TunedConfig(
        mir_fingerprint=program_mir_fingerprint(bfs_program),
        bucket=shape_bucket(graph=graph),
        target=tuned_target,
        objective_s=0.001, baseline_s=0.002, trials=3,
    ))
    with repro.serve(store, workers=1) as svc:
        svc.run(bfs_program, graph, root=0)
        svc.run(bfs_program, graph, root=1)
        snap = svc.stats()
    label = bfs_program.fingerprint[:12]
    assert snap["programs"][label]["tuned_hits"] == 2
    assert snap["queries"]["tuned_hits"] == 2
    assert snap["tuning"]["hits"] == 2
    assert snap["tuning"]["enabled"] is True


def test_serving_pinned_target_wins_over_tuning(bfs_program, graph, tmp_path):
    store = str(tmp_path / "registry")
    TuningCache(tuning_dir_for(store)).put(TunedConfig(
        mir_fingerprint=program_mir_fingerprint(bfs_program),
        bucket=shape_bucket(graph=graph),
        target=Target(shuffle=False),
        objective_s=0.001, baseline_s=0.002, trials=3,
    ))
    pinned = Target()
    with repro.serve(store, workers=1, target=pinned) as svc:
        svc.run(bfs_program, graph, root=0)
        snap = svc.stats()
    assert snap["queries"]["tuned_hits"] == 0


def test_serving_autotune_off_skips_lookup(bfs_program, graph, tmp_path):
    store = str(tmp_path / "registry")
    TuningCache(tuning_dir_for(store)).put(TunedConfig(
        mir_fingerprint=program_mir_fingerprint(bfs_program),
        bucket=shape_bucket(graph=graph),
        target=Target(shuffle=False),
        objective_s=0.001, baseline_s=0.002, trials=3,
    ))
    with repro.serve(store, workers=1, autotune=False) as svc:
        svc.run(bfs_program, graph, root=0)
        snap = svc.stats()
    assert snap["queries"]["tuned_hits"] == 0
    assert snap["tuning"]["enabled"] is False


# --------------------------------------------------------------------------
# satellite: report() degrades to None estimates, cost model tolerates
# --------------------------------------------------------------------------


def test_xla_estimates_none_compiled():
    from repro.core.accelerator import _xla_estimates

    est = _xla_estimates(None)
    assert est == {"flops": None, "bytes_accessed": None, "arg_bytes": None,
                   "out_bytes": None, "temp_bytes": None}


def test_xla_estimates_raising_executable_degrades_to_none():
    from repro.core.accelerator import _xla_estimates

    class Hostile:
        def cost_analysis(self):
            raise NotImplementedError("no cost analysis on this backend")

        def memory_analysis(self):
            raise RuntimeError("interpreted executables have no memory stats")

    est = _xla_estimates(Hostile())
    assert est["flops"] is None
    assert est["bytes_accessed"] is None
    assert est["temp_bytes"] is None


def test_report_survives_missing_cost_analysis(bfs_program, graph,
                                               monkeypatch):
    import repro.core.accelerator as accel_mod

    monkeypatch.setattr(
        accel_mod, "_xla_estimates",
        lambda compiled: {"flops": None, "bytes_accessed": None,
                          "arg_bytes": None, "out_bytes": None,
                          "temp_bytes": None},
    )
    acc = bfs_program.lower(graph=graph)
    rep = acc.report()
    assert rep.kernels
    # static lane-count fallback keeps flops usable for the cost model
    assert all((k.flops or 0) > 0 for k in rep.kernels)
    assert all(k.bytes_accessed is None for k in rep.kernels)


def test_cost_score_tolerates_none_estimates():
    class Plan:
        kind = "edge"
        direction = "auto"
        flops = None
        bytes_accessed = None

    score = AutoTuner._cost_score(Target(), [Plan()])
    assert score > 0


def test_objective_falls_back_to_wall_time():
    assert AutoTuner._objective_from_trace(None, 0.5) == 0.5
    assert AutoTuner._objective_from_trace({"spans": {}}, 0.5) == 0.5
    trace = {"spans": {"launch:k": {"total_s": 0.2}, "run": {"total_s": 9.0}}}
    assert AutoTuner._objective_from_trace(trace, 0.5) == pytest.approx(0.2)


def test_tuner_parameter_validation():
    with pytest.raises(ValueError):
        AutoTuner(TuningCache(), reps=0)
    with pytest.raises(ValueError):
        AutoTuner(TuningCache(), margin=1.0)
    with pytest.raises(ValueError):
        AutoTuner(TuningCache(), max_candidates=0)
