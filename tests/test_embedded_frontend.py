"""Embedded front-end tests: GraphProgram builder, AST lowering, the
`.gt` round-trip (embedded -> to_source -> parse -> identical MIR hash)
for every supported construct, the shared MIR-keyed Program cache, the
embedded-vs-text equivalence matrix on both backends with passes on/off,
and front-end diagnostics (Python file/lineno for embedded, line/col
excerpts for text)."""
import numpy as np
import pytest

import repro
from repro.algorithms import sources
from repro.algorithms.embedded import (
    BFS_ECP_EMBEDDED,
    PAGERANK_EMBEDDED,
    build_bfs_ecp,
    build_pagerank,
)
from repro.core import CompileOptions, analyze, mir, parse
from repro.core.program import ProgramError, clear_program_cache, compile_program
from repro.frontend import (
    FrontendError,
    GraphProgram,
    exp,
    leakyrelu,
    sigmoid,
    swap,
    to_float,
)
from repro.graph import generators


def roundtrip_fingerprint(p: GraphProgram) -> None:
    """embedded -> to_source() -> parse -> analyze must be MIR-hash
    identical to analyzing the builder's FIR directly."""
    direct = p.fingerprint()
    via_text = mir.fingerprint(analyze(parse(p.to_source())))
    assert direct == via_text, (
        "round-trip fingerprint mismatch:\n" + p.to_source()
    )


def base_program(name="t"):
    """A minimal program skeleton: edgeset, vertexset, one int prop."""
    p = GraphProgram(name)
    edges = p.edgeset("edges")
    vertices = p.vertexset("vertices")
    prop = p.vertex_prop("val", int)
    return p, edges, vertices, prop


# ---------------------------------------------------------------------------
# round-trip property tests: one per supported construct
# ---------------------------------------------------------------------------


def test_roundtrip_arithmetic_and_unary():
    p, edges, vertices, val = base_program()
    out = p.vertex_prop("out", int)

    @p.vertex_kernel
    def k(v):
        out[v] = (val[v] + 2) * 3 - val[v] / 2
        val[v] = -out[v]

    @p.main
    def main():
        vertices.process(k)

    roundtrip_fingerprint(p)


def test_roundtrip_all_comparisons_and_boolops():
    p, edges, vertices, val = base_program()
    flag = p.vertex_prop("flag", int)

    @p.vertex_kernel
    def k(v):
        if (val[v] == 0) or (val[v] != 1) and (val[v] < 5):
            flag[v] = 1
        if (val[v] <= 2) and (val[v] > -3) or (val[v] >= 7):
            flag[v] = 2
        if not (val[v] == 4):  # noqa: SIM201 - exercises `not` lowering
            flag[v] = 3

    @p.main
    def main():
        vertices.process(k)

    roundtrip_fingerprint(p)


def test_roundtrip_reductions():
    p, edges, vertices, val = base_program()
    lo = p.vertex_prop("lo", int)
    hi = p.vertex_prop("hi", int)

    @p.edge_kernel
    def k(src, dst):
        lo[dst] = min(lo[dst], val[src])
        hi[dst] = max(val[src], hi[dst])  # reversed args also recognized
        val[dst] += 1
        lo[dst] -= 2
        hi[dst] *= 3

    @p.main
    def main():
        edges.process(k)

    # min/max spelled as calls must lower to the DSL reduce statements
    src_text = p.to_source()
    assert "lo[dst] min= val[src];" in src_text
    assert "hi[dst] max= val[src];" in src_text
    roundtrip_fingerprint(p)


def test_roundtrip_if_elif_else():
    p, edges, vertices, val = base_program()

    @p.vertex_kernel
    def k(v):
        if val[v] == 0:
            val[v] = 1
        elif val[v] == 1:
            val[v] = 2
        else:
            val[v] = 3

    @p.main
    def main():
        vertices.process(k)

    roundtrip_fingerprint(p)


def test_roundtrip_accumulator_and_const_index():
    p, edges, vertices, val = base_program()
    total = p.vertex_prop("total", int)

    @p.edge_kernel
    def k(src, dst):
        val[dst] += 1
        total[0] = total[0] + 1  # normalizes to += in both front-ends

    @p.main
    def main():
        edges.process(k)

    roundtrip_fingerprint(p)
    kern = analyze(p.to_fir()).kernels["k"]
    assert "total" in kern.accumulators


def test_roundtrip_neighbor_loop():
    p, edges, vertices, val = base_program()
    acc = p.vertex_prop("acc", int)

    @p.vertex_kernel
    def gather(v):
        for ngh in v.getNeighbors():
            acc[ngh] = min(acc[ngh], val[v])

    @p.main
    def main():
        vertices.process(gather)

    roundtrip_fingerprint(p)
    assert analyze(p.to_fir()).kernels["gather"].has_neighbor_loop


def test_roundtrip_weighted_edges_and_weight_write():
    p = GraphProgram("w")
    edges = p.edgeset("edges", weight=float)
    vertices = p.vertexset("vertices")
    feat = p.vertex_prop("feat", float)

    @p.edge_kernel
    def score(src, dst, weight):
        weight = leakyrelu(feat[src] + feat[dst], 0.2)

    @p.main
    def main():
        edges.process(score)

    roundtrip_fingerprint(p)
    assert analyze(p.to_fir()).kernels["score"].writes_weight


def test_roundtrip_builtins_and_captured_constants():
    eps = 0.25  # captured Python float, inlined as a literal
    p = GraphProgram("b")
    p.edgeset("edges")
    vertices = p.vertexset("vertices")
    x = p.vertex_prop("x", float)

    @p.vertex_kernel
    def k(v):
        x[v] = sigmoid(exp(to_float(vertices.size()))) + abs(x[v]) - eps

    @p.main
    def main():
        vertices.process(k)

    assert "0.25" in p.to_source()
    roundtrip_fingerprint(p)


def test_roundtrip_host_control_flow_and_swap():
    p = GraphProgram("h")
    p.edgeset("edges")
    vertices = p.vertexset("vertices")
    a = p.vertex_prop("a", float)
    b = p.vertex_prop("b", float)
    iters = p.scalar("iters", int, init=3)
    thresh = p.scalar("thresh", float)  # required parameter (no init)

    @p.vertex_kernel
    def step(v):
        if a[v] > thresh:
            b[v] = a[v] * 0.5

    @p.main
    def main():
        vertices.init(step)
        i: int = 0
        while i < iters:
            vertices.process(step)
            swap(a, b)
            i = i + 1

    roundtrip_fingerprint(p)
    prog = compile_program(p)
    assert prog.params["thresh"].required
    assert not prog.params["iters"].required


def test_roundtrip_host_helper_and_degrees_and_path():
    p = GraphProgram("d")
    edges = p.edgeset("edges", path="graph.el")
    vertices = p.vertexset("vertices")
    deg = p.vertex_prop("deg", int, init=edges.out_degrees())
    indeg = p.vertex_prop("indeg", int, init=edges.in_degrees())

    @p.vertex_kernel
    def k(v):
        deg[v] = deg[v] + indeg[v]

    @p.host
    def helper():
        vertices.process(k)

    @p.main
    def main():
        helper()

    assert 'load("graph.el")' in p.to_source()
    roundtrip_fingerprint(p)
    mod = analyze(p.to_fir())
    assert mod.degree_props == {"deg": "out", "indeg": "in"}
    assert "helper" in mod.host.host_funcs


def test_roundtrip_edge_prop():
    p = GraphProgram("ep")
    p.edgeset("edges")
    vertices = p.vertexset("vertices")
    p.edge_prop("mark", int)
    val = p.vertex_prop("val", int)

    @p.vertex_kernel
    def k(v):
        val[v] = 0

    @p.main
    def main():
        vertices.process(k)

    roundtrip_fingerprint(p)
    mod = analyze(p.to_fir())
    assert mod.properties["mark"].is_edge
    assert mod.memory.buffers["mark"][0] == "E"


def test_python_name_independent_of_dsl_name():
    p = GraphProgram("n")
    p.edgeset("edges")
    v_ = p.vertexset("vertices")
    renamed = p.vertex_prop("tuple", int)  # DSL name is a Python builtin

    @p.vertex_kernel
    def k(v):
        renamed[v] = 0

    @p.main
    def main():
        v_.process(k)

    assert "tuple[v] = 0;" in p.to_source()
    roundtrip_fingerprint(p)


# ---------------------------------------------------------------------------
# twins: fingerprints, shared cache, equivalence matrix
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def graph():
    return generators.power_law(300, 2000, seed=7)


def test_twins_match_text_fingerprints():
    for embedded, text in [
        (BFS_ECP_EMBEDDED, sources.BFS_ECP),
        (PAGERANK_EMBEDDED, sources.PAGERANK),
    ]:
        assert embedded.fingerprint() == mir.fingerprint(analyze(parse(text)))
        roundtrip_fingerprint(embedded)


def test_builders_produce_fresh_equal_programs():
    assert build_bfs_ecp().fingerprint() == BFS_ECP_EMBEDDED.fingerprint()
    assert build_pagerank().fingerprint() == PAGERANK_EMBEDDED.fingerprint()


def test_embedded_and_text_share_one_cache_entry():
    clear_program_cache()
    p_emb = repro.compile(BFS_ECP_EMBEDDED)
    p_txt = repro.compile(sources.BFS_ECP)
    assert p_emb is p_txt  # one artifact, two front-ends
    # distinct options still recompile
    p_opt = repro.compile(BFS_ECP_EMBEDDED, CompileOptions(passes="none"))
    assert p_opt is not p_emb


def test_cache_normalizes_cosmetic_text_differences():
    clear_program_cache()
    a = repro.compile(sources.BFS_ECP)
    b = repro.compile(sources.BFS_ECP + "\n% trailing comment\n")
    assert a is b


@pytest.mark.parametrize("passes", ["default", "none"])
@pytest.mark.parametrize("backend", ["local", "distributed"])
def test_equivalence_matrix_bfs(graph, backend, passes):
    opts = CompileOptions(passes=passes)
    clear_program_cache()
    r_emb = repro.compile(BFS_ECP_EMBEDDED, opts).bind(
        graph, backend=backend).run(root=3)
    clear_program_cache()  # force an independent compile of the text twin
    r_txt = repro.compile(sources.BFS_ECP, opts).bind(
        graph, backend=backend).run(root=3)
    np.testing.assert_array_equal(
        r_emb.properties["old_level"], r_txt.properties["old_level"])


@pytest.mark.parametrize("passes", ["default", "none"])
@pytest.mark.parametrize("backend", ["local", "distributed"])
def test_equivalence_matrix_pagerank(graph, backend, passes):
    opts = CompileOptions(passes=passes)
    clear_program_cache()
    r_emb = repro.compile(PAGERANK_EMBEDDED, opts).bind(
        graph, backend=backend).run(iters=5)
    clear_program_cache()
    r_txt = repro.compile(sources.PAGERANK, opts).bind(
        graph, backend=backend).run(iters=5)
    np.testing.assert_array_equal(
        r_emb.properties["rank"], r_txt.properties["rank"])


def test_runners_accept_embedded_source(graph):
    from repro.algorithms import run_bfs, run_pagerank

    lv_emb, _ = run_bfs(graph, root=3, source=BFS_ECP_EMBEDDED)
    lv_txt, _ = run_bfs(graph, root=3)
    np.testing.assert_array_equal(lv_emb, lv_txt)
    pr_emb, _ = run_pagerank(graph, iters=5, source=PAGERANK_EMBEDDED)
    pr_txt, _ = run_pagerank(graph, iters=5)
    np.testing.assert_array_equal(pr_emb, pr_txt)


def test_runner_argv_is_fresh_per_bind(graph):
    """A caller mutating its session's argv must not poison later binds."""
    from repro.algorithms import runners

    assert isinstance(runners._ARGV, tuple)
    prog = compile_program(sources.WCC)
    s1 = prog.bind(graph, argv=list(runners._ARGV))
    s1.backend.engine.argv.append("poison")
    s2 = prog.bind(graph, argv=list(runners._ARGV))
    assert "poison" not in s2.backend.engine.argv


# ---------------------------------------------------------------------------
# diagnostics: embedded errors carry Python file/lineno, text errors
# carry line/col + a caret excerpt
# ---------------------------------------------------------------------------


def test_embedded_error_reports_python_location():
    p, edges, vertices, val = base_program()
    with pytest.raises(FrontendError) as ei:
        @p.vertex_kernel
        def bad(v):
            val[v] = undeclared_name  # noqa: F821
    assert ei.value.filename and ei.value.filename.endswith(".py")
    assert ei.value.lineno is not None
    assert "undeclared_name" in str(ei.value)
    assert f"{ei.value.filename}:{ei.value.lineno}" in str(ei.value)


def test_embedded_rejects_unsupported_python():
    p, edges, vertices, val = base_program()
    with pytest.raises(FrontendError, match="return"):
        @p.vertex_kernel
        def k1(v):
            return val[v]
    with pytest.raises(FrontendError, match="chained"):
        @p.vertex_kernel
        def k2(v):
            if 0 < val[v] < 5:
                val[v] = 1
    with pytest.raises(FrontendError, match="undeclared"):
        @p.main
        def m():
            x = 1  # missing `x: int = 1` annotation
    with pytest.raises(FrontendError, match="builtin"):
        @p.vertex_kernel
        def k3(v):
            val[v] = len(val)  # arbitrary Python calls don't lower


def test_embedded_builder_misuse():
    p, edges, vertices, val = base_program()
    with pytest.raises(FrontendError, match="duplicate"):
        p.vertex_prop("val", int)
    with pytest.raises(FrontendError, match="keyword"):
        p.vertex_prop("while", int)
    with pytest.raises(FrontendError, match="one edgeset"):
        p.edgeset("edges2")
    with pytest.raises(FrontendError, match="unweighted"):
        @p.edge_kernel
        def k(src, dst, weight):
            weight = 1.0
    # handles and builtin stubs are not executable Python
    with pytest.raises(FrontendError, match="outside a decorated kernel"):
        val[0]
    with pytest.raises(FrontendError, match="device builtin"):
        to_float(1)

    @p.vertex_kernel
    def ok(v):
        val[v] = 0

    with pytest.raises(FrontendError, match="not directly callable"):
        ok(3)

    @p.main
    def main():
        vertices.process(ok)

    with pytest.raises(FrontendError, match="already has a @main"):
        @p.main
        def main2():
            vertices.process(ok)


def test_embedded_program_without_main_fails():
    p, edges, vertices, val = base_program()
    with pytest.raises(FrontendError, match="no @main"):
        p.to_fir()


def test_embedded_semantic_error_becomes_programerror():
    p, edges, vertices, val = base_program()

    @p.vertex_kernel
    def k(v):
        while val[v] > 0:  # while is host-only: semantic rejection
            val[v] = 0

    @p.main
    def main():
        vertices.process(k)

    with pytest.raises(ProgramError, match="host-only"):
        compile_program(p)


def test_text_parse_error_has_line_col_and_excerpt():
    bad = "element Vertex end\nelement Edge end\nconst x int = 1;\n"
    with pytest.raises(ProgramError) as ei:
        repro.compile(bad)
    assert ei.value.line == 3 and ei.value.col == 9
    msg = str(ei.value)
    assert "const x int = 1;" in msg and "^" in msg


def test_text_lex_error_has_location():
    with pytest.raises(ProgramError) as ei:
        repro.compile("element Vertex end\nconst $bad: int = 1;\n")
    assert ei.value.line == 2
    assert "^" in str(ei.value)


def test_text_semantic_error_surfaces_line():
    bad = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const acc: vector{Vertex}(int);
func k(src: Vertex, dst: Vertex, w: int)
    acc[dst] += w;
end
func main()
    edges.process(k);
end
"""
    with pytest.raises(ProgramError) as ei:
        repro.compile(bad)
    assert "unweighted" in str(ei.value)
    assert ei.value.line == 7
    assert "func k(src: Vertex, dst: Vertex, w: int)" in str(ei.value)


def test_compile_rejects_non_source():
    with pytest.raises(ProgramError, match="GraphProgram"):
        repro.compile(42)


def test_cross_program_handle_rejected():
    p1 = GraphProgram("one")
    p1.edgeset("edges")
    p1.vertexset("vertices")
    foreign = p1.vertex_prop("rank", float)

    p2, edges2, vertices2, val2 = base_program("two")
    with pytest.raises(FrontendError, match="belongs to GraphProgram 'one'"):
        @p2.vertex_kernel
        def k(v):
            val2[v] = 0
            foreign[v] = 1.0  # p1's handle inside a p2 kernel


def test_compile_wraps_builder_errors_as_programerror():
    p, edges, vertices, val = base_program()  # no @main yet
    with pytest.raises(ProgramError, match="no @main"):
        repro.compile(p)


def test_edgeset_path_rejects_unescapable_strings():
    p = GraphProgram("bad")
    with pytest.raises(FrontendError, match="escape"):
        p.edgeset("edges", path='a"b')


def test_embedded_identity_memo_and_invalidation():
    p, edges, vertices, val = base_program()

    @p.vertex_kernel
    def k(v):
        val[v] = 0

    @p.main
    def main():
        vertices.process(k)

    clear_program_cache()
    a = repro.compile(p)
    assert p._identity is not None  # memoized after the first compile
    assert repro.compile(p) is a  # repeat hits the memo + program cache
    # a new declaration invalidates the memo: recompile sees the change
    extra = p.vertex_prop("extra", int)
    assert p._identity is None
    assert extra.name in repro.compile(p, CompileOptions(passes="none")).source
