"""Training substrate tests: optimizers, schedules, microbatching,
quantization properties, straggler monitor, data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import smoke_config
from repro.data import SyntheticLM
from repro.distributed.compression import StragglerMonitor
from repro.models import Model
from repro.train import OptConfig, init_state, make_train_step
from repro.train import optimizer as opt_mod


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("qwen3-0.6b")
    m = Model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=64, global_batch=8, seed=0)
    return cfg, m, params, data


@pytest.mark.parametrize("quantized", [False, True])
def test_training_reduces_loss(setup, quantized):
    cfg, m, params, data = setup
    ocfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60, quantized=quantized)
    st_ = init_state(params, ocfg)
    ts = jax.jit(make_train_step(m, ocfg, n_microbatches=2))
    p = params
    l0 = lN = None
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        p, st_, metrics = ts(p, st_, b)
        if i == 0:
            l0 = float(metrics["loss"])
        lN = float(metrics["loss"])
    assert lN < l0 - 0.2, f"no learning: {l0} -> {lN}"


def test_microbatch_equivalence(setup):
    """Accumulated microbatch gradients == single-shot gradients on the
    same global batch (Adam's sqrt(v) step-1 sensitivity makes post-update
    params ill-conditioned for comparison, so compare the grads)."""
    cfg, m, params, data = setup
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    grad_fn = jax.jit(jax.grad(lambda p, mb: m.loss(p, mb)[0]))
    g1 = grad_fn(params, b)
    nm = 4
    mbs = jax.tree.map(
        lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]), b
    )
    acc = jax.tree.map(lambda p: np.zeros(p.shape, np.float32), params)
    for i in range(nm):
        gi = grad_fn(params, jax.tree.map(lambda x: x[i], mbs))
        acc = jax.tree.map(lambda a, g: a + np.asarray(g, np.float32), acc, gi)
    acc = jax.tree.map(lambda g: g / nm, acc)
    for a, b_ in zip(jax.tree.leaves(g1), jax.tree.leaves(acc)):
        np.testing.assert_allclose(np.asarray(a), b_, rtol=1e-3, atol=1e-6)


def test_lr_schedule_shape():
    ocfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(opt_mod.lr_schedule(ocfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=0.2)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)


def test_grad_clipping():
    ocfg = OptConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, total_steps=1,
                     weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    st_ = init_state(params, ocfg)
    new_p, _ = opt_mod.apply_updates(params, grads, st_, ocfg)
    # clipped global norm = 1 -> per-element grad 0.5 -> adam update ~ lr
    assert np.all(np.isfinite(np.asarray(new_p["w"])))
    assert np.abs(np.asarray(new_p["w"])).max() < 2.0


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 2000),
    power=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-6, 1e4),
)
def test_quantization_error_bound(n, power, seed, scale):
    """Nonlinear int8 code: per-element error <= power/127 * blockmax."""
    r = np.random.default_rng(seed)
    x = (r.normal(size=n) * scale).astype(np.float32)
    if power == 4:
        x = np.abs(x)
    q, s = opt_mod._quant(jnp.asarray(x), power=power)
    back = np.asarray(opt_mod._dequant(q, s, x.shape, power=power))
    blocks = opt_mod._blocks(jnp.asarray(x))
    bmax = np.maximum(np.asarray(jnp.max(jnp.abs(blocks), axis=1)), 1e-20)
    tol = (power / 127.0) * np.repeat(bmax, opt_mod.QBLOCK)[:n] + 1e-12
    assert (np.abs(back - x) <= tol).all()


def test_quantization_preserves_sign_and_zero():
    x = jnp.asarray([-1.0, 0.0, 1e-9, 5.0], jnp.float32)
    q, s = opt_mod._quant(x, power=2)
    back = np.asarray(opt_mod._dequant(q, s, x.shape, power=2))
    assert back[0] < 0 and back[1] == 0 and back[3] > 0


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, warmup=3)
    for _ in range(10):
        assert not mon.record(1.0)
    assert mon.record(5.0)  # 5x EWMA -> flagged
    assert mon.flags[-1]["action"] == "rebalance-or-replace"
    assert not mon.record(1.0)  # EWMA not poisoned by the straggler
    assert mon.ewma == pytest.approx(1.0, rel=0.05)


def test_data_determinism_and_restart_safety(setup):
    cfg, _, _, _ = setup
    d1 = SyntheticLM(cfg, 32, 4, seed=3)
    d2 = SyntheticLM(cfg, 32, 4, seed=3)
    b1 = d1.batch(17)
    b2 = d2.batch(17)  # a "restarted job" regenerating step 17
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = d1.batch(18)
    assert not np.array_equal(b1["labels"], b3["labels"])


def test_data_sharding_partitions_batch(setup):
    cfg, _, _, _ = setup
    d = SyntheticLM(cfg, 32, 8, seed=4)
    full_rows = [d.batch(5, shard=s, shards=4)["labels"] for s in range(4)]
    assert all(r.shape[0] == 2 for r in full_rows)
    # distinct shards see distinct data
    assert not np.array_equal(full_rows[0], full_rows[1])
