"""Graph substrate property tests (storage, partitioning, generators)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.graph import generators
from repro.graph.datasets import TABLE_II, make_dataset


@settings(max_examples=20, deadline=None)
@given(
    n_v=st.integers(2, 300),
    n_e=st.integers(1, 2000),
    n_parts=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_partition_by_dst_properties(n_v, n_e, n_parts, seed):
    g = generators.uniform_random(n_v, n_e, seed=seed)
    pe = g.partition_by_dst(n_parts)
    # the edge order is a permutation
    assert sorted(pe.edge_order.tolist()) == list(range(g.n_edges))
    p_eff = pe.n_partitions
    for p in range(p_eff):
        src, dst, _ = pe.partition_edges(p)
        lo, hi = pe.vertex_bounds[p], pe.vertex_bounds[p + 1]
        # every dst lands in the partition's vertex range
        assert ((dst >= lo) & (dst < hi)).all()
        # ascending src inside each partition (paper §III-D)
        assert (np.diff(src) >= 0).all()


@settings(max_examples=20, deadline=None)
@given(n_v=st.integers(2, 200), n_e=st.integers(1, 1500), seed=st.integers(0, 2**31 - 1))
def test_csr_roundtrip(n_v, n_e, seed):
    g = generators.uniform_random(n_v, n_e, seed=seed)
    indptr, indices, eids = g.csr
    assert indptr[-1] == g.n_edges
    # CSR reconstructs the edge multiset
    recon = set()
    for v in range(n_v):
        for i in range(indptr[v], indptr[v + 1]):
            recon.add((v, int(indices[i]), int(eids[i])))
    orig = {(int(s), int(d), i) for i, (s, d) in enumerate(zip(g.src, g.dst))}
    assert recon == orig


def test_relabel_by_degree_preserves_structure():
    g = generators.power_law(200, 1500, seed=3)
    g2, old2new = g.relabel_by_degree()
    # edges map 1:1
    assert g2.n_edges == g.n_edges
    np.testing.assert_array_equal(old2new[g.src], g2.src)
    np.testing.assert_array_equal(old2new[g.dst], g2.dst)
    # hubs first: new id 0 has the max total degree
    tot = g.out_degree.astype(np.int64) + g.in_degree
    assert tot[g.degree_rank[0]] == tot.max()
    d2 = g2.out_degree.astype(np.int64) + g2.in_degree
    assert d2[0] == tot.max()


def test_dst_sort_perm():
    g = generators.uniform_random(100, 800, seed=4)
    perm = g.dst_sort_perm
    assert (np.diff(g.dst[perm]) >= 0).all()


def test_star_graph_hub_detection():
    g = generators.star(64)
    assert g.degree_rank[0] == 0  # the hub


@pytest.mark.parametrize("short", list(TABLE_II))
def test_table_ii_datasets_scaled(short):
    g = make_dataset(short, scale=0.001, seed=0)
    spec = TABLE_II[short]
    assert g.n_vertices >= 64
    assert g.n_edges >= 256
    # degree ratio approximates the published average
    target = spec.n_edges / spec.n_vertices
    got = g.n_edges / g.n_vertices
    assert 0.3 * target <= got <= 3 * target


def test_rmat_skew():
    g = generators.rmat(10, 16, seed=0)
    deg = np.sort(g.out_degree)[::-1]
    # power-law-ish: top 1% of vertices own >5% of edges
    top = deg[: max(1, len(deg) // 100)].sum()
    assert top / g.n_edges > 0.05


def test_edge_list_loader(tmp_path):
    p = tmp_path / "g.txt"
    p.write_text("# comment\n0 1 2.5\n1 2 1.0\n2 0 3.5\n")
    g = generators.load_edge_list(str(p))
    assert g.n_vertices == 3 and g.n_edges == 3 and g.weighted
    np.testing.assert_allclose(g.weights, [2.5, 1.0, 3.5])


# ---------------------------------------------------------------------------
# device-ABI dtype stability (int32 CSR/CSC) + shape-bucket padding
# ---------------------------------------------------------------------------


def test_csr_csc_all_int32():
    """Regression: indptr used to be int64 while indices/edge_perm were
    int32 — device buffers and AOT shape signatures need one stable ABI."""
    g = generators.power_law(200, 1500, seed=3)
    for indptr, indices, eids in (g.csr, g.csc):
        assert indptr.dtype == np.int32, "indptr must be int32"
        assert indices.dtype == np.int32
        assert eids.dtype == np.int32
    assert g.src.dtype == np.int32 and g.dst.dtype == np.int32
    assert g.csr[0][-1] == g.n_edges and g.csc[0][-1] == g.n_edges


def test_indptr_overflow_guard():
    from repro.graph.storage import MAX_INT32_EDGES, _indptr_from_degrees

    deg = np.array([1, 2, 3], dtype=np.int64)
    out = _indptr_from_degrees(deg, 6)
    assert out.dtype == np.int32
    np.testing.assert_array_equal(out, [0, 1, 3, 6])
    with pytest.raises(OverflowError, match="int32 indptr"):
        _indptr_from_degrees(deg, MAX_INT32_EDGES)


def test_pad_to_bucket():
    g = generators.power_law(100, 700, seed=1, weighted=True)
    p = g.pad_to(128, 768)
    assert (p.n_vertices, p.n_edges) == (128, 768)
    # real edges untouched, padding edges are self-loops on the last vertex
    np.testing.assert_array_equal(p.src[:700], g.src)
    np.testing.assert_array_equal(p.dst[:700], g.dst)
    assert (p.src[700:] == 127).all() and (p.dst[700:] == 127).all()
    np.testing.assert_array_equal(p.weights[:700], g.weights)
    # real vertices keep their degrees
    np.testing.assert_array_equal(p.out_degree[:100], g.out_degree)
    np.testing.assert_array_equal(p.in_degree[:100], g.in_degree)
    # no-op and error cases
    assert g.pad_to(100, 700) is g
    with pytest.raises(ValueError, match="smaller"):
        g.pad_to(50, 700)
    with pytest.raises(ValueError, match="padding vertex"):
        g.pad_to(100, 768)
