"""Middle-end tests: Property Detector, transforms, MIR (paper §III-B2)."""
import pytest

from repro.core import analyze, parse
from repro.core import mir
from repro.core.semantic import SemanticError
from repro.algorithms import sources


def _mod(src):
    return analyze(parse(src))


def test_kernel_classification():
    m = _mod(sources.BFS_ECP)
    assert m.kernels["reset"].kind is mir.KernelKind.VERTEX
    assert m.kernels["EdgeTraversal"].kind is mir.KernelKind.EDGE
    assert "main" not in m.kernels
    assert m.host.main is not None


def test_property_detector_bfs():
    m = _mod(sources.BFS_ECP)
    et = m.kernels["EdgeTraversal"]
    assert any(r.prop == "old_level" and r.pattern is mir.IndexPattern.SRC for r in et.reads)
    assert any(
        w.prop == "tuple" and w.pattern is mir.IndexPattern.DST and w.reduce_op == "min"
        for w in et.writes
    )
    assert "level" in et.scalar_reads
    vu = m.kernels["VertexUpdate"]
    assert "activeVertex" in vu.accumulators


def test_memory_plan_covers_all_properties():
    m = _mod(sources.PPR)
    for p in m.properties:
        assert p in m.memory.buffers
    # PPR needs >2 vertex properties — beyond ThunderGP's fixed template
    assert len(m.memory.buffers) >= 6


def test_rmw_normalization():
    """`P[0] = P[0] + 1` becomes `P[0] += 1` (§III-C2 unroll+reduce)."""
    m = _mod(sources.BFS_ECP)
    vu = m.kernels["VertexUpdate"]
    accum_writes = [w for w in vu.writes if w.prop == "activeVertex"]
    assert accum_writes and accum_writes[0].reduce_op == "+"


def test_raw_decoupling_sssp():
    """Fig. 5 -> Fig. 6: SP read at src and tuple written at dst are in
    different buffers already; a kernel writing what it gathers must be
    snapshot-decoupled."""
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex, int) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const SP: vector{Vertex}(int);
func sssp(src: Vertex, dst: Vertex, weight: int)
    SP[dst] min= (SP[src] + weight);
end
func main()
    edges.process(sssp);
end
"""
    m = _mod(src)
    assert m.kernels["sssp"].snapshot_props == {"SP"}


def test_frontier_detection():
    m = _mod(sources.BFS_ECP)
    assert m.kernels["EdgeTraversal"].frontier is not None
    assert m.kernels["EdgeTraversal"].frontier.props == {"old_level"}
    # VertexApply has no guard
    assert m.kernels["VertexApply"].frontier is None


def test_neighbor_loop_detection():
    m = _mod(sources.BFS_HYBRID)
    assert m.kernels["VertexTraversal"].has_neighbor_loop


def test_weight_write_detection():
    m = _mod(sources.CGAW)
    assert m.kernels["score"].writes_weight
    assert m.kernels["normalize"].writes_weight


def test_degree_property():
    m = _mod(sources.PAGERANK)
    assert m.degree_props == {"deg": "out"}


def test_describe_lists_modules():
    m = _mod(sources.SSSP)
    text = m.describe()
    assert "kernel relax [edge]" in text
    assert "buffer SP" in text
    assert "frontier-check" in text


def test_semantic_errors():
    with pytest.raises(SemanticError):
        _mod("element Vertex end\nfunc main() end")  # no edgeset
    with pytest.raises(SemanticError):
        _mod(
            "element Vertex end\nelement Edge end\n"
            "const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);\n"
            "func f(src: Vertex, dst: Vertex, w: int) end\nfunc main() end"
        )  # weighted func on unweighted edgeset
    with pytest.raises(SemanticError):
        _mod(
            "element Vertex end\nelement Edge end\n"
            "const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);\n"
            "func f(v: Vertex)\n  while (true)\n  end\nend\nfunc main() end"
        )  # device while loop
