"""End-to-end system behaviour: user-authored DSL source -> compiled
accelerator program -> results, plus engine-level invariants the paper's
system guarantees."""
import numpy as np

from repro.core import CompileOptions, Engine, compile_source, run_source
from repro.graph import generators


USER_PROGRAM = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const indeg: vector{Vertex}(int);
const total: vector{Vertex}(int);

func initz(v: Vertex)
    indeg[v] = 0;
end
func count(src: Vertex, dst: Vertex)
    indeg[dst] += 1;
    total[0] = total[0] + 1;
end
func main()
    vertices.init(initz);
    edges.process(count);
end
"""


def test_user_program_end_to_end():
    g = generators.uniform_random(50, 400, seed=0)
    res = run_source(USER_PROGRAM, g, CompileOptions.full(), argv=["prog", "mem"])
    np.testing.assert_array_equal(res.properties["indeg"], g.in_degree)
    assert res.properties["total"][0] == g.n_edges  # accumulator reduction


def test_engine_reuse_and_stats():
    g = generators.power_law(100, 600, seed=1)
    module = compile_source(USER_PROGRAM)
    eng = Engine(module, g, CompileOptions.full(), argv=["p", "g"])
    res = eng.run()
    assert res.stats.kernel_launches == {"initz": 1, "count": 1}
    assert res.stats.wall_time_s > 0


def test_hybrid_direction_switching_actually_switches():
    """Fig. 2: the engine must launch BOTH VCP and ECP kernels when the
    frontier crosses the 5% threshold."""
    import repro
    from repro.algorithms import sources

    g = generators.power_law(2000, 30000, seed=2)
    session = repro.compile(sources.BFS_HYBRID, CompileOptions.full()).bind(g)
    res = session.run(root=int(np.argmax(g.out_degree)))  # reachable frontier
    launches = res.stats.kernel_launches
    assert launches.get("VertexTraversal", 0) > 0, "VCP never used"
    assert launches.get("EdgeTraversal", 0) > 0, "ECP never used"


def test_multiple_properties_beyond_template_limit():
    """Table III: arbitrary numbers of graph properties (ThunderGP caps at
    its template's fixed set)."""
    src_parts = [
        "element Vertex end",
        "element Edge end",
        "const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);",
        "const vertices: vertexset{Vertex} = edges.getVertices();",
    ]
    n_props = 12
    for i in range(n_props):
        src_parts.append(f"const p{i}: vector{{Vertex}}(float);")
    body = "\n".join(f"    p{i}[v] = {i}.0;" for i in range(n_props))
    src_parts.append(f"func setall(v: Vertex)\n{body}\nend")
    src_parts.append("func main()\n    vertices.init(setall);\nend")
    g = generators.uniform_random(30, 100, seed=3)
    res = run_source("\n".join(src_parts), g, CompileOptions.full())
    for i in range(n_props):
        np.testing.assert_allclose(res.properties[f"p{i}"], float(i))


def test_edge_weight_mutation_visible_in_results():
    """Table III: the accelerator may WRITE edge weights (CGAW's need)."""
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex, float) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
func double_w(src: Vertex, dst: Vertex, weight: float)
    weight = weight * 2.0;
end
func main()
    edges.process(double_w);
end
"""
    g = generators.uniform_random(20, 80, seed=4, weighted=True)
    res = run_source(src, g, CompileOptions.full())
    np.testing.assert_allclose(res.properties["weight"], g.weights * 2.0, rtol=1e-6)


def test_vcp_and_ecp_same_result():
    """The same algorithm expressed vertex-centric and edge-centric
    produces identical results (programming-model flexibility)."""
    ecp = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const acc: vector{Vertex}(float);
const val: vector{Vertex}(float);
func initv(v: Vertex)
    val[v] = to_float(original_id(v));
    acc[v] = 0.0;
end
func push(src: Vertex, dst: Vertex)
    acc[dst] += val[src];
end
func main()
    vertices.init(initv);
    edges.process(push);
end
"""
    vcp = ecp.replace(
        """func push(src: Vertex, dst: Vertex)
    acc[dst] += val[src];
end""",
        """func push(v: Vertex)
    for ngh in v.getNeighbors()
        acc[ngh] += val[v];
    end
end""",
    ).replace("edges.process(push);", "vertices.process(push);")
    g = generators.power_law(150, 900, seed=5)
    r1 = run_source(ecp, g, CompileOptions.full())
    r2 = run_source(vcp, g, CompileOptions.full())
    np.testing.assert_allclose(r1.properties["acc"], r2.properties["acc"], rtol=1e-5)


def test_pull_direction_in_neighbors():
    src = """
element Vertex end
element Edge end
const edges: edgeset{Edge}(Vertex, Vertex) = load(argv[1]);
const vertices: vertexset{Vertex} = edges.getVertices();
const s: vector{Vertex}(float);
const val: vector{Vertex}(float);
func initv(v: Vertex)
    val[v] = 1.0;
    s[v] = 0.0;
end
func pull(v: Vertex)
    var acc: float = 0.0;
    for ngh in v.getInNeighbors()
        acc += val[ngh];
    end
    s[v] = acc;
end
func main()
    vertices.init(initv);
    vertices.process(pull);
end
"""
    g = generators.uniform_random(60, 500, seed=6)
    res = run_source(src, g, CompileOptions.full())
    np.testing.assert_allclose(res.properties["s"], g.in_degree.astype(float), rtol=1e-6)
