"""Streaming graph updates + incremental recomputation.

Acceptance criteria of the streaming PR:

* ``GraphDelta`` + ``GraphData.apply_updates`` mutate in place through the
  ``pad_to`` padding slack — tombstoned removals, free-slot additions,
  logical-count maintenance, periodic compaction — and never change the
  physical shape (same GraphShape bucket);
* logical vs padded counts: globally-normalized programs (PageRank's
  ``vertices.size()``) agree between padded and unpadded runs;
* ``GraphShape.bucket_for`` rounds to shared geometric buckets;
* incremental re-convergence is **bit-identical** to a from-scratch run for
  monotone programs (BFS / SSSP / WCC) after random additions-only deltas,
  across passes default/none and the local + distributed backends, and
  PageRank-class programs transparently fall back to a full recompute;
* in-bucket updates perform no new lowering (Accelerator-backed sessions
  keep ``stats.compile_time_s == 0`` across updates);
* concurrent SessionPool queries during ``update()`` never observe a torn
  version: every result is pinned to the version it was admitted under.
"""
import threading

import numpy as np
import pytest

import repro
from repro.algorithms import sources
from repro.core import CompileOptions
from repro.core.accelerator import GraphShape
from repro.core.passes import analyze_incremental
from repro.graph import generators
from repro.graph.storage import GraphData, GraphDelta, GraphUpdateError
from repro.streaming import StreamingSession

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings
from _hypothesis_compat import strategies as st


def _bucketed(n_vertices=300, n_edges=1800, *, weighted=False, seed=1):
    g = generators.uniform_random(n_vertices, n_edges, weighted=weighted,
                                  seed=seed)
    shape = GraphShape.bucket_for(g.n_vertices, g.n_edges, weighted=weighted)
    return g.pad_to(shape.n_vertices, shape.n_edges)


def _random_delta(rng, graph, k, *, weighted=False):
    lv = graph.n_vertices_logical
    edges = rng.integers(0, lv, size=(k, 2)).astype(np.int32)
    w = rng.integers(1, 64, size=k).astype(np.float32) if weighted else None
    return GraphDelta(added_edges=edges, added_weights=w)


def _assert_same_result(a, b):
    assert set(a.properties) == set(b.properties)
    for name in a.properties:
        x, y = np.asarray(a.properties[name]), np.asarray(b.properties[name])
        assert x.dtype == y.dtype, name
        np.testing.assert_array_equal(x, y, err_msg=name)
    assert a.host_env == b.host_env


# ---------------------------------------------------------------------------
# GraphDelta + apply_updates (storage layer)
# ---------------------------------------------------------------------------


def test_graph_delta_validation_and_introspection():
    d = GraphDelta(added_edges=[(0, 1), (2, 3)], removed_edges=[(4, 5)])
    assert d.n_added == 2 and d.n_removed == 1
    assert not d.additions_only
    assert sorted(d.endpoints().tolist()) == [0, 1, 2, 3, 4, 5]
    assert GraphDelta(added_edges=[(7, 8)]).additions_only
    with pytest.raises(ValueError):
        GraphDelta(added_edges=np.zeros((2, 3)))
    with pytest.raises(ValueError):
        GraphDelta(added_edges=[(0, 1)], added_weights=[1.0, 2.0])


def test_apply_updates_add_and_remove_in_place():
    g = GraphData(4, src=[0, 1, 2], dst=[1, 2, 3]).pad_to(6, 8)
    assert g.n_vertices_logical == 4 and g.n_edges_logical == 3
    buffers = (g.src, g.dst)
    v0 = g.version

    g.apply_updates(GraphDelta(added_edges=[(3, 0), (0, 2)]))
    assert g.n_edges_logical == 5 and g.n_edges == 8  # physical unchanged
    assert g.src is buffers[0] and g.dst is buffers[1]  # in place
    assert g.version == v0 + 1
    real = ~g._free_slot_mask()
    pairs = set(zip(g.src[real].tolist(), g.dst[real].tolist()))
    assert pairs == {(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)}

    g.apply_updates(GraphDelta(removed_edges=[(1, 2)]))
    assert g.n_edges_logical == 4
    real = ~g._free_slot_mask()
    pairs = set(zip(g.src[real].tolist(), g.dst[real].tolist()))
    assert (1, 2) not in pairs and len(pairs) == 4
    # tombstones are pad-vertex self-loops: degree caches see them as pad
    assert int(g.out_degree[:4].sum()) == 4


def test_apply_updates_errors():
    g = GraphData(4, src=[0, 1, 2], dst=[1, 2, 3]).pad_to(6, 8)
    with pytest.raises(GraphUpdateError, match="vertex"):
        g.apply_updates(GraphDelta(added_edges=[(0, 99)]))
    with pytest.raises(GraphUpdateError, match="present"):
        g.apply_updates(GraphDelta(removed_edges=[(3, 3)]))
    with pytest.raises(GraphUpdateError, match="bucket_for"):
        g.apply_updates(GraphDelta(added_edges=[(0, 1)] * 50))
    # failed updates must not partially mutate
    assert g.n_edges_logical == 3
    # unpadded graphs have no free slots at all
    flat = GraphData(4, src=[0, 1, 2], dst=[1, 2, 3])
    with pytest.raises(GraphUpdateError):
        flat.apply_updates(GraphDelta(added_edges=[(0, 3)]))


def test_apply_updates_duplicate_edges_and_compact():
    g = GraphData(4, src=[0, 1, 1, 2], dst=[1, 2, 2, 3]).pad_to(6, 12)
    # duplicate (1, 2): removal takes out exactly one instance per request
    g.apply_updates(GraphDelta(removed_edges=[(1, 2)]))
    real = ~g._free_slot_mask()
    assert list(zip(g.src[real], g.dst[real])).count((1, 2)) == 1
    g.apply_updates(GraphDelta(added_edges=[(3, 0)]), compact=True)
    # after compaction every real edge precedes every free slot
    real = ~g._free_slot_mask()
    assert real[: g.n_edges_logical].all() and not real[g.n_edges_logical:].any()


def test_logical_counts_propagate_through_transforms():
    g = generators.uniform_random(50, 300, weighted=True, seed=0)
    p = g.pad_to(64, 512)
    assert (p.n_vertices_logical, p.n_edges_logical) == (50, 300)
    assert p.relabel_by_degree()[0].n_vertices_logical == 50
    assert p.with_unit_weights().n_edges_logical == 300


# ---------------------------------------------------------------------------
# GraphShape.bucket_for (satellite: shared geometric buckets)
# ---------------------------------------------------------------------------


def test_bucket_for_geometric_rounding():
    s = GraphShape.bucket_for(300, 1800)
    assert s.n_vertices >= 300 * 1.12 and s.n_edges >= 1800 * 1.12
    # deterministic + shared across nearby sizes
    assert s == GraphShape.bucket_for(300, 1800)
    assert s == GraphShape.bucket_for(310, 1850)
    # monotone in both arguments
    big = GraphShape.bucket_for(3000, 18000)
    assert big.n_vertices > s.n_vertices and big.n_edges > s.n_edges
    assert GraphShape.bucket_for(10, 50, weighted=True).weighted
    # padding edges requires at least one pad vertex to hang self-loops on
    exact_v = GraphShape.bucket_for(1024, 100)
    assert exact_v.n_vertices > 1024


def test_bucket_for_pads_and_binds():
    g = generators.uniform_random(200, 1200, seed=3)
    shape = GraphShape.bucket_for(g.n_vertices, g.n_edges)
    padded = g.pad_to(shape.n_vertices, shape.n_edges)
    assert GraphShape.of(padded) == shape
    acc = repro.compile(sources.BFS_ECP).lower(graph=g, bucket=True)
    assert acc.shape == shape
    r = acc.bind(padded).run(root=1)
    assert r.stats.compile_time_s >= 0


# ---------------------------------------------------------------------------
# Logical vs padded counts (satellite: PageRank teleport mass)
# ---------------------------------------------------------------------------


def test_pagerank_padded_matches_unpadded():
    """vertices.size() must read the LOGICAL count: 1/|V| teleport mass and
    the rank vector on real vertices agree between padded and unpadded runs
    (allclose: padding changes float segment-reduction partition sizes)."""
    g = generators.uniform_random(120, 700, seed=2)
    program = repro.compile(sources.PAGERANK)
    base = program.bind(g).run(iters=10)
    padded = _bucketed(120, 700, seed=2)
    padded_r = program.bind(padded).run(iters=10)
    np.testing.assert_allclose(
        np.asarray(padded_r.properties["rank"])[:120],
        np.asarray(base.properties["rank"]),
        rtol=1e-5, atol=1e-7,
    )


# ---------------------------------------------------------------------------
# Monotonicity analysis (MIR-level)
# ---------------------------------------------------------------------------


MONOTONE_EXPECT = {
    "BFS_ECP": ("unit_distance", True),
    "BFS_HYBRID": ("unit_distance", True),
    "SSSP": ("weighted_distance", True),
    "WCC": ("label", True),
    "PAGERANK": (None, False),
    "PPR": (None, False),
    "CGAW": (None, False),
    "KCORE": (None, False),
}


@pytest.mark.parametrize("name", sorted(MONOTONE_EXPECT))
def test_analyze_incremental_verdicts(name):
    kind, monotone = MONOTONE_EXPECT[name]
    info = analyze_incremental(repro.compile(getattr(sources, name)).module)
    assert info.monotone is monotone, info.reasons
    if monotone:
        assert info.incremental_ok and info.template.kind == kind
    else:
        assert not info.incremental_ok and info.reasons


# ---------------------------------------------------------------------------
# Incremental == from-scratch (the tentpole equivalence)
# ---------------------------------------------------------------------------


STREAM_CASES = {
    "bfs": (sources.BFS_ECP, {"root": 3}, False),
    "sssp": (sources.SSSP, {"root": 3}, True),
    "wcc": (sources.WCC, {}, False),
    "pagerank": (sources.PAGERANK, {"iters": 6}, False),
}


@pytest.mark.parametrize("passes", ["default", "none"])
@pytest.mark.parametrize("algo", sorted(STREAM_CASES))
def test_incremental_matches_from_scratch_local(algo, passes):
    src, params, weighted = STREAM_CASES[algo]
    program = repro.compile(src, CompileOptions(passes=passes))
    rng = np.random.default_rng(11)
    ss = StreamingSession(program, _bucketed(weighted=weighted))
    try:
        ss.run(**params)
        for _ in range(3):
            ss.update(_random_delta(rng, ss.graph, 20, weighted=weighted))
            got = ss.run(**params)
            ref = program.bind(ss.graph).run(**params)
            _assert_same_result(got, ref)
            assert got.version == ss.version
        if algo == "pagerank":
            assert ss.incremental_runs == 0 and ss.full_runs == 4
        else:
            assert ss.incremental_runs == 3 and ss.full_runs == 1
    finally:
        ss.close()


def test_incremental_matches_from_scratch_distributed(subproc):
    out = subproc(
        """
import numpy as np, repro
from repro.algorithms import sources
from repro.core.accelerator import GraphShape
from repro.graph import generators
from repro.graph.storage import GraphDelta
from repro.streaming import StreamingSession

rng = np.random.default_rng(5)
for src, params, weighted in [
    (sources.BFS_ECP, {"root": 2}, False),
    (sources.SSSP, {"root": 2}, True),
    (sources.WCC, {}, False),
]:
    g = generators.uniform_random(160, 900, weighted=weighted, seed=4)
    shape = GraphShape.bucket_for(g.n_vertices, g.n_edges, weighted=weighted)
    program = repro.compile(src)
    ss = StreamingSession(program, g.pad_to(shape.n_vertices, shape.n_edges),
                          backend="distributed")
    ss.run(**params)
    for _ in range(2):
        lv = ss.graph.n_vertices_logical
        e = rng.integers(0, lv, size=(12, 2)).astype(np.int32)
        w = rng.integers(1, 64, size=12).astype(np.float32) if weighted else None
        ss.update(GraphDelta(added_edges=e, added_weights=w))
        got = ss.run(**params)
        ref = program.bind(ss.graph, backend="distributed").run(**params)
        for p in ref.properties:
            np.testing.assert_array_equal(
                np.asarray(got.properties[p]), np.asarray(ref.properties[p]),
                err_msg=p)
        assert got.host_env == ref.host_env
    assert ss.incremental_runs == 2
    ss.close()
print("DIST-STREAM-OK")
"""
    )
    assert "DIST-STREAM-OK" in out


def test_removals_fall_back_to_full_recompute():
    program = repro.compile(sources.BFS_ECP)
    ss = StreamingSession(program, _bucketed())
    try:
        ss.run(root=3)
        real = np.flatnonzero(~ss.graph._free_slot_mask())[:4]
        rem = np.stack([ss.graph.src[real], ss.graph.dst[real]], axis=1)
        ss.update(GraphDelta(removed_edges=rem))
        got = ss.run(root=3)
        ref = program.bind(ss.graph).run(root=3)
        _assert_same_result(got, ref)
        assert ss.incremental_runs == 0 and ss.full_runs == 2
    finally:
        ss.close()


def test_rebucket_on_overflow_is_transparent():
    program = repro.compile(sources.BFS_ECP)
    ss = StreamingSession(program, _bucketed())
    try:
        slack = ss.graph.n_edges - ss.graph.n_edges_logical
        rng = np.random.default_rng(0)
        ss.update(_random_delta(rng, ss.graph, slack + 16))
        assert ss.rebuckets == 1 and ss.version == 1
        got = ss.run(root=3)
        ref = program.bind(ss.graph).run(root=3)
        _assert_same_result(got, ref)
    finally:
        ss.close()


def test_same_version_cache_hit_and_repair_reuse():
    program = repro.compile(sources.BFS_ECP)
    ss = StreamingSession(program, _bucketed())
    try:
        first = ss.run(root=3)
        assert ss.run(root=3) is first and ss.cache_hits == 1
        ss.update(_random_delta(np.random.default_rng(1), ss.graph, 8))
        repaired = ss.run(root=3)
        assert repaired is not first and ss.incremental_runs == 1
        assert ss.run(root=3) is repaired  # repaired result is re-cached
    finally:
        ss.close()


# ---------------------------------------------------------------------------
# No re-lowering across in-bucket updates (accelerator warm path)
# ---------------------------------------------------------------------------


def test_in_bucket_update_performs_no_new_lowering():
    g = generators.uniform_random(200, 1200, seed=6)
    program = repro.compile(sources.BFS_ECP)
    acc = program.lower(graph=g, bucket=True)
    padded = g.pad_to(acc.shape.n_vertices, acc.shape.n_edges)
    ss = StreamingSession(program, padded, accelerator=acc)
    try:
        ss.run(root=0)  # warm-up
        rng = np.random.default_rng(2)
        for step in range(3):
            ss.update(_random_delta(rng, ss.graph, 10))
            full = ss.run(root=step + 1)  # unseen param: full run, warm library
            assert full.stats.compile_time_s == 0.0
            inc = ss.run(root=0)  # repaired: pure host work
            assert inc.stats.compile_time_s == 0.0
    finally:
        ss.close()


# ---------------------------------------------------------------------------
# Concurrency: SessionPool queries racing update()
# ---------------------------------------------------------------------------


def test_concurrent_queries_never_observe_torn_versions():
    program = repro.compile(sources.BFS_ECP)
    ss = StreamingSession(program, _bucketed(), pool_size=2, compact_every=0)
    try:
        ss.warmup(root=0)
        rng = np.random.default_rng(3)
        errors = []
        done = threading.Event()

        def updater():
            try:
                for _ in range(6):
                    ss.update(_random_delta(rng, ss.graph, 6))
            except Exception as exc:  # pragma: no cover
                errors.append(exc)
            finally:
                done.set()

        t = threading.Thread(target=updater)
        t.start()
        futures = []
        while not done.is_set():
            futures.extend(ss.submit(root=r % 5) for r in range(4))
            for f in futures[-4:]:
                f.result()
        t.join()
        assert not errors
        results = [f.result() for f in futures]
        assert {r.version for r in results} <= set(range(ss.version + 1))
        # quiesced: current-version answers equal a fresh independent bind
        _assert_same_result(ss.run(root=1), program.bind(ss.graph).run(root=1))
        assert ss.updates == 6
    finally:
        ss.close()


# ---------------------------------------------------------------------------
# Property-based equivalence (hypothesis when available)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_deltas=st.integers(min_value=1, max_value=3),
    k=st.integers(min_value=1, max_value=30),
)
def test_random_deltas_preserve_equivalence(seed, n_deltas, k):
    rng = np.random.default_rng(seed)
    algo = ["bfs", "sssp", "wcc"][seed % 3]
    src, params, weighted = STREAM_CASES[algo]
    program = repro.compile(src)
    ss = StreamingSession(program, _bucketed(150, 900, weighted=weighted,
                                             seed=seed % 7))
    try:
        ss.run(**params)
        for _ in range(n_deltas):
            ss.update(_random_delta(rng, ss.graph, k, weighted=weighted))
        got = ss.run(**params)
        ref = program.bind(ss.graph).run(**params)
        _assert_same_result(got, ref)
        assert ss.incremental_runs >= 1
    finally:
        ss.close()


def test_hypothesis_compat_flag_is_boolean():
    assert HAVE_HYPOTHESIS in (True, False)
