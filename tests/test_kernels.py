"""Pallas kernel tests: shape/dtype sweeps + hypothesis property tests,
all asserting allclose against the pure-jnp ref.py oracles (interpret
mode — the kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------------
# shuffle_reduce
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,v", [(64, 16), (1000, 300), (4096, 512), (513, 1024), (7, 5)])
@pytest.mark.parametrize("op", ["+", "min", "max"])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_shuffle_reduce_sweep(n, v, op, dtype):
    idx = jnp.asarray(RNG.integers(0, v, n).astype(np.int32))
    vals = jnp.asarray(RNG.integers(-50, 50, n).astype(dtype))
    got = ops.shuffle_reduce(vals, idx, v, op)
    want = ref.shuffle_reduce_ref(vals, idx, v, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    v=st.integers(1, 700),
    op=st.sampled_from(["+", "min", "max"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_shuffle_reduce_property(n, v, op, seed):
    r = np.random.default_rng(seed)
    idx = jnp.asarray(r.integers(0, v, n).astype(np.int32))
    vals = jnp.asarray(r.normal(size=n).astype(np.float32))
    got = ops.shuffle_reduce(vals, idx, v, op)
    want = ref.shuffle_reduce_ref(vals, idx, v, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-5)


def test_shuffle_reduce_empty_bins():
    """Bins receiving no update hold the reduction identity."""
    idx = jnp.asarray([2, 2, 2], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    out = np.asarray(ops.shuffle_reduce(vals, idx, 5, "min"))
    assert out[2] == 1.0 and np.isinf(out[0]) and np.isinf(out[4])


# --------------------------------------------------------------------------
# edge_stream (fused gather->apply->shuffle->reduce)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("e,v", [(128, 32), (3000, 400), (5000, 123)])
@pytest.mark.parametrize("apply_op", ["add", "mul", "src"])
@pytest.mark.parametrize("reduce_op", ["+", "min", "max"])
def test_edge_stream_sweep(e, v, apply_op, reduce_op):
    sv = jnp.asarray(RNG.normal(size=e).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=e).astype(np.float32))
    dst = jnp.asarray(RNG.integers(0, v, e).astype(np.int32))
    act = jnp.asarray(RNG.random(e) < 0.4)
    got = ops.edge_stream(sv, w, dst, act, v, apply_op, reduce_op)
    want = ref.edge_stream_ref(sv, w, dst, act, v, apply_op, reduce_op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(e=st.integers(1, 1500), v=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
def test_edge_stream_property(e, v, seed):
    r = np.random.default_rng(seed)
    sv = jnp.asarray(r.normal(size=e).astype(np.float32))
    w = jnp.asarray(r.normal(size=e).astype(np.float32))
    dst = jnp.asarray(r.integers(0, v, e).astype(np.int32))
    act = jnp.asarray(r.random(e) < 0.5)
    got = ops.edge_stream(sv, w, dst, act, v, "add", "min")
    want = ref.edge_stream_ref(sv, w, dst, act, v, "add", "min")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# moe dispatch
# --------------------------------------------------------------------------


@pytest.mark.parametrize("e,c,d,bc", [(8, 256, 64, 128), (4, 128, 32, 128), (16, 512, 128, 128)])
def test_moe_gather_sweep(e, c, d, bc):
    sizes = np.minimum(RNG.multinomial(e * c // 2, np.ones(e) / e), c).astype(np.int32)
    aligned = ((sizes + bc - 1) // bc) * bc
    offs = np.zeros(e, np.int32)
    offs[1:] = np.cumsum(aligned)[:-1]
    tbuf = int(offs[-1] + aligned[-1])
    tok = jnp.asarray(RNG.normal(size=(tbuf, d)).astype(np.float32))
    got = ops.moe_gather(tok, jnp.asarray(offs), jnp.asarray(sizes), c)
    want = ref.moe_gather_ref(tok, jnp.asarray(offs), jnp.asarray(sizes), c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_moe_scatter_roundtrip():
    e, c, d = 4, 128, 32
    sizes = jnp.asarray([100, 17, 0, 128], jnp.int32)
    offs = jnp.asarray([0, 128, 256, 384], jnp.int32)
    tok = jnp.asarray(RNG.normal(size=(640, d)).astype(np.float32))
    binned = ref.moe_gather_ref(tok, offs, sizes, c)
    back = ref.moe_scatter_ref(binned, offs, sizes, 640)
    # rows inside groups round-trip; padding rows are zero
    for ei in range(e):
        o, s = int(offs[ei]), int(sizes[ei])
        np.testing.assert_allclose(np.asarray(back[o : o + s]), np.asarray(tok[o : o + s]))


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,hkv,lq,lk,dh", [
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 128, 128, 64),
    (1, 4, 1, 1, 256, 64),  # decode shape
    (1, 2, 2, 100, 100, 32),  # ragged
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_attention_sweep(b, h, hkv, lq, lk, dh, causal, window):
    q = jnp.asarray(RNG.normal(size=(b, h, lq, dh)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, lk, dh)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, lk, dh)).astype(np.float32))
    got = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    b, h, l, dh = 1, 2, 128, 64
    q = jnp.asarray(RNG.normal(size=(b, h, l, dh)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, h, l, dh)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, h, l, dh)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=3e-2, atol=3e-2
    )
