"""Front-end tests: lexer, parser, FIR grammar (paper §III-B1)."""
import pytest

from repro.core import parse
from repro.core.lexer import LexError, tokenize
from repro.core.parser import ParseError
from repro.core import fir
from repro.algorithms import sources


def test_tokenize_basics():
    toks = tokenize("const level: int = 1; % comment\nfunc f(v: Vertex) end")
    kinds = [t.kind for t in toks]
    assert "kw" in kinds and "ident" in kinds and kinds[-1] == "eof"
    texts = [t.text for t in toks]
    assert "%" not in texts  # comments stripped
    assert "level" in texts


def test_tokenize_reduce_ops():
    toks = tokenize("tuple[dst] min= level + 1; x max= 2; y += 3;")
    ops = [t.text for t in toks if t.kind == "op"]
    assert "min=" in ops and "max=" in ops and "+=" in ops


def test_tokenize_min_as_call_not_reduce():
    toks = tokenize("x = min(a, b);")
    assert any(t.kind == "ident" and t.text == "min" for t in toks)


def test_lex_errors():
    with pytest.raises(LexError):
        tokenize('x = "unclosed')
    with pytest.raises(LexError):
        tokenize("x = $bad;")


@pytest.mark.parametrize(
    "src_name", ["BFS_ECP", "BFS_HYBRID", "PAGERANK", "SSSP", "PPR", "CGAW", "WCC", "KCORE"]
)
def test_parse_all_algorithms(src_name):
    prog = parse(getattr(sources, src_name))
    assert isinstance(prog, fir.Program)
    assert prog.func("main") is not None
    assert len(prog.elements) == 2


def test_parse_structure_bfs():
    prog = parse(sources.BFS_ECP)
    et = prog.func("EdgeTraversal")
    assert [p.name for p in et.params] == ["src", "dst"]
    assert isinstance(et.body[0], fir.If)
    assert isinstance(et.body[0].then_body[0], fir.ReduceAssign)
    assert et.body[0].then_body[0].op == "min"
    main = prog.func("main")
    whiles = [s for s in main.body if isinstance(s, fir.While)]
    assert len(whiles) == 1


def test_parse_weighted_edgeset():
    prog = parse(sources.SSSP)
    edges = [c for c in prog.consts if isinstance(c.type, fir.EdgesetType)][0]
    assert edges.type.weighted and edges.type.weight == "int"


def test_parse_errors():
    with pytest.raises(ParseError):
        parse("const x int = 1;")  # missing ':'
    with pytest.raises(ParseError):
        parse("func f(v: Vertex) x = ; end")
    with pytest.raises(ParseError):
        parse("element Vertex")  # missing end


def test_fir_dump_reparses():
    """dump() output is itself valid Graphitron for every algorithm
    (round-trip: parse -> dump -> parse is structurally stable)."""
    for name in ("BFS_ECP", "PAGERANK", "SSSP", "PPR", "CGAW", "WCC", "KCORE"):
        prog = parse(getattr(sources, name))
        text = fir.dump(prog)
        prog2 = parse(text)
        assert len(prog2.funcs) == len(prog.funcs)
        assert fir.dump(prog2) == text  # fixpoint
