"""Per-architecture smoke tests (reduced configs) + decode consistency +
published-size checks for the FULL configs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import Model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    if cfg.frontend != "none":
        return {
            "embeds": jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + loss on CPU, finite, and
    output shapes are correct."""
    cfg = smoke_config(arch)
    m = Model(cfg, dtype=jnp.float32)
    params = m.init(KEY)
    batch = _batch(cfg)
    logits, _ = jax.jit(m.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaNs in logits"
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0  # init ~ uniform


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if get_config(a).has_decoder])
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)  # no drops
    m = Model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    if cfg.frontend != "none":
        inp = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
        batch = {"embeds": inp}
        step_in = lambda t: inp[:, t : t + 1]
    else:
        toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        batch = {"tokens": toks}
        step_in = lambda t: toks[:, t : t + 1]
    full, _ = jax.jit(m.forward)(params, batch)
    cache = m.init_cache(b, s)
    dec = jax.jit(m.decode_step)
    for t in range(s):
        lg, cache = dec(params, cache, step_in(t))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        scale = float(jnp.max(jnp.abs(full[:, t])))
        assert err < 2e-3 * max(scale, 1.0), f"{arch} t={t}: {err}"


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "zamba2-2.7b"])
def test_ring_buffer_swa_decode(arch):
    """Decoding past the sliding window: cache stays O(window)."""
    cfg = smoke_config(arch)
    m = Model(cfg, dtype=jnp.float32)
    params = m.init(jax.random.PRNGKey(2))
    b, s = 1, 48  # window is 32
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _ = jax.jit(m.forward)(params, {"tokens": toks})
    cache = m.init_cache(b, s)
    kv = cache.get("attn", cache.get("kv"))
    assert kv["k"].shape[-3] == cfg.sliding_window  # ring, not full length
    dec = jax.jit(m.decode_step)
    for t in range(s):
        lg, cache = dec(params, cache, toks[:, t : t + 1])
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 5e-3, f"{arch} t={t}: {err}"


def test_mla_absorbed_equals_naive_decode():
    from repro.models import attention as attn

    cfg = smoke_config("deepseek-v2-236b")
    p, _ = attn.mla_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 1, cfg.d_model), jnp.float32)
    cache = attn.mla_init_cache(cfg, 2, 8, jnp.float32)
    # place one token at pos 0 first
    y0, cache = attn.mla_decode(p, cfg, cache, x, jnp.int32(0), absorb=True)
    y_abs, _ = attn.mla_decode(p, cfg, cache, x, jnp.int32(1), absorb=True)
    y_naive, _ = attn.mla_decode(p, cfg, cache, x, jnp.int32(1), absorb=False)
    np.testing.assert_allclose(np.asarray(y_abs), np.asarray(y_naive), rtol=1e-4, atol=1e-5)


EXPECTED_PARAMS_B = {
    "granite-20b": (20.3, 0.5),
    "h2o-danube-3-4b": (4.0, 0.3),
    "deepseek-coder-33b": (33.3, 0.7),
    "qwen3-0.6b": (0.6, 0.1),
    "deepseek-v2-236b": (236.0, 4.0),
    "kimi-k2-1t-a32b": (1028.0, 30.0),
    "hubert-xlarge": (0.96, 0.1),
    "zamba2-2.7b": (2.4, 0.4),
    "xlstm-125m": (0.15, 0.05),
    "qwen2-vl-2b": (1.54, 0.2),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_counts(arch):
    """Analytic param counts match published model sizes."""
    cfg = get_config(arch)
    want, tol = EXPECTED_PARAMS_B[arch]
    got = cfg.param_count() / 1e9
    assert abs(got - want) <= tol, f"{arch}: {got:.2f}B vs {want}B"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count() / 1e9
    assert 28 <= active <= 40  # a32b


def test_moe_drop_and_balance_metrics():
    from repro.models import moe as moe_mod

    cfg = smoke_config("deepseek-v2-236b")
    p, _ = moe_mod.moe_init(jax.random.PRNGKey(4), cfg, jnp.float32)
    x = jax.random.normal(KEY, (4, 64, cfg.d_model), jnp.float32)
    _, aux = moe_mod.moe_apply(p, cfg, x, capacity_factor=0.5)
    assert float(aux["drop_fraction"]) > 0  # tight capacity must drop
    _, aux2 = moe_mod.moe_apply(p, cfg, x, capacity_factor=16.0)
    assert float(aux2["drop_fraction"]) == 0.0
    assert float(aux2["load_balance_loss"]) > 0


def test_param_spec_tree_matches_params():
    """Logical-axis trees are structurally identical to the param trees."""
    for arch in ARCH_IDS:
        m = Model(smoke_config(arch))
        params = m.abstract_params()
        specs = m.param_specs()
        s1 = jax.tree_util.tree_structure(params)
        s2 = jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda x: 0, specs, is_leaf=lambda x: isinstance(x, tuple))
        )
        assert s1 == s2, arch
