"""Accelerator artifact tests: the compile -> lower -> bind split.

Covers the acceptance criteria of the Target/Accelerator PR:

* ``Target`` is hashable, validates its fields, and absorbs the legacy
  CompileOptions substrate kwargs through the compat shim;
* ``program.lower(target, shape).bind(graph)`` produces results
  bit-identical to ``program.bind(graph)`` — and two different graphs of
  one shape bucket bound to ONE accelerator match independently compiled
  Programs, on the local and distributed backends;
* ``accelerator.save`` / ``repro.load_accelerator`` round-trips are
  bit-identical to the in-process path across all 8 algorithms x
  local/distributed x passes default/none;
* ``accelerator.report()`` exposes the per-kernel launch plan and
  resource estimates;
* warm binds skip compilation (EngineStats.compile_time_s == 0) and the
  Program cache is a bounded LRU with observable counters.
"""
import numpy as np
import pytest

import repro
from repro.algorithms import sources
from repro.core import CompileOptions, Target
from repro.core.accelerator import (
    AcceleratorError,
    GraphShape,
    accelerator_fingerprint,
)
from repro.graph import generators

ALGORITHMS = {
    "bfs": (sources.BFS_ECP, {"root": 3}, "old_level"),
    "bfs_hybrid": (sources.BFS_HYBRID, {"root": 3}, "old_level"),
    "pagerank": (sources.PAGERANK, {"iters": 5}, "rank"),
    "sssp": (sources.SSSP, {"root": 3}, "SP"),
    "ppr": (sources.PPR, {"source": 3, "max_iters": 8}, "PR_old"),
    "cgaw": (sources.CGAW, {}, "weight"),
    "wcc": (sources.WCC, {}, "comp"),
    "kcore": (sources.KCORE, {"k": 3}, "alive"),
}


@pytest.fixture(scope="module")
def graph():
    return generators.power_law(200, 1400, seed=5, weighted=True)


@pytest.fixture(scope="module")
def twin_graph():
    """A different graph with the identical (|V|, |E|, weighted) bucket."""
    return generators.power_law(200, 1400, seed=11, weighted=True)


def _assert_results_equal(a, b):
    assert set(a.properties) == set(b.properties)
    for name in a.properties:
        np.testing.assert_array_equal(a.properties[name], b.properties[name])
    assert a.host_env == b.host_env


# ---------------------------------------------------------------------------
# Target + CompileOptions split
# ---------------------------------------------------------------------------


def test_target_is_hashable_and_validates():
    t = Target()
    assert hash(t) == hash(Target())
    assert t.backend_name == "local"
    with pytest.raises(ValueError, match="kind"):
        Target(kind="gpu-cluster")
    with pytest.raises(ValueError, match="dtype_policy"):
        Target(dtype_policy="bf16")
    with pytest.raises(ValueError, match="n_devices"):
        Target(n_devices=-1)
    with pytest.raises(ValueError, match="partition_vertices"):
        Target(partition_vertices=0)


def test_target_auto_partitions():
    assert Target(partition_vertices=1000).auto_partitions(5000) == 5
    assert Target(n_partitions=7).auto_partitions(5000) == 7
    assert Target().auto_partitions(10) == 1


def test_compile_options_shim_maps_legacy_kwargs():
    opts = CompileOptions(burst=False, pallas=True)
    assert opts.burst is False and opts.pallas is True and opts.cache is True
    t = Target.from_options(opts)
    assert t.burst is False and t.pallas is True and t.cache is True
    # canonicalization: default-valued legacy kwargs don't split the cache
    assert CompileOptions(pallas=False) == CompileOptions()
    assert repr(CompileOptions(burst=True)) == repr(CompileOptions())
    with pytest.raises(TypeError, match="moved to repro.Target"):
        CompileOptions(mesh_shape=(2,))


def test_compile_options_ablation_constructors_roundtrip():
    base = CompileOptions.baseline()
    t = Target.from_options(base)
    assert (t.burst, t.cache, t.shuffle, t.compact_frontier) == (False,) * 4
    assert base.passes == "none"
    only = CompileOptions.with_only("shuffle")
    ts = Target.from_options(only)
    assert ts.shuffle is True and ts.burst is False
    assert Target.baseline() == Target.from_options(base)
    assert Target.with_only("shuffle") == ts


def test_target_dict_roundtrip():
    t = Target(kind="distributed", n_devices=2, burst=False, interpret=True)
    assert Target.from_dict(t.to_dict()) == t
    with pytest.raises(ValueError, match="unknown Target fields"):
        Target.from_dict({"kind": "local", "hbm_channels": 32})


# ---------------------------------------------------------------------------
# GraphShape buckets
# ---------------------------------------------------------------------------


def test_graph_shape_of_and_bucketed(graph):
    s = GraphShape.of(graph)
    assert s == GraphShape(200, 1400, True)
    b = s.bucketed(v_round=256, e_round=1024)
    assert b == GraphShape(256, 2048, True)
    padded = graph.pad_to(b.n_vertices, b.n_edges)
    assert b.accepts(padded) and not b.accepts(graph)


def test_lower_requires_shape():
    prog = repro.compile(sources.BFS_ECP)
    with pytest.raises(repro.ProgramError, match="shape bucket"):
        prog.lower()


def test_weighted_program_needs_weighted_bucket():
    prog = repro.compile(sources.SSSP)
    with pytest.raises(AcceleratorError, match="weighted"):
        prog.lower(shape=GraphShape(100, 500, weighted=False))


def test_bind_shape_mismatch_raises(graph):
    prog = repro.compile(sources.BFS_ECP)
    acc = prog.lower(shape=GraphShape(100, 500))
    with pytest.raises(AcceleratorError, match="pad the"):
        acc.bind(graph)


# ---------------------------------------------------------------------------
# lower -> bind equivalence + shape-bucket rebinding
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["local", "distributed"])
def test_bucket_rebinding_matches_independent_programs(graph, twin_graph, backend):
    """Two different generated graphs of one padded bucket bound to ONE
    accelerator produce results identical to independently compiled+bound
    Programs (the satellite acceptance test)."""
    src = sources.SSSP
    prog = repro.compile(src)
    target = Target.from_options(prog.options, kind=backend)
    acc = prog.lower(target, GraphShape.of(graph))
    for g in (graph, twin_graph):
        ref = repro.compile(src).bind(g, backend=backend).run(root=3)
        got = acc.bind(g).run(root=3)
        _assert_results_equal(ref, got)
    assert acc.binds == 2


def test_rebind_after_warm_is_compile_free(graph, twin_graph):
    acc = repro.compile(sources.BFS_ECP).lower(graph=graph)
    first = acc.bind(graph).run(root=3)
    rebind = acc.bind(twin_graph).run(root=3)
    # the AOT full-stream path is born warm; the rebind reuses every
    # compacted-subset bucket the first bind compiled
    assert rebind.stats.compile_time_s == 0.0
    assert rebind.stats.run_time_s == rebind.stats.wall_time_s
    assert first.stats.wall_time_s > 0


def test_run_many_and_batch_on_accelerator_session(graph):
    """Batched rerouting works on accelerator-backed sessions (trace_full)."""
    acc = repro.compile(sources.BFS_ECP).lower(graph=graph)
    sess = acc.bind(graph)
    sets = [{"root": int(r)} for r in (0, 3, 9, 17)]
    batched = sess.run_many(sets)
    for p, r in zip(sets, batched):
        _assert_results_equal(repro.compile(sources.BFS_ECP).bind(graph).run(**p), r)


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def test_report_contents(graph):
    acc = repro.compile(sources.PAGERANK).lower(graph=graph)
    rep = acc.report()
    assert rep.shape == GraphShape.of(graph)
    assert rep.state_bytes > 0 and rep.gb_bytes > 0
    assert rep.live_buffer_peak_bytes >= rep.state_bytes + rep.gb_bytes
    assert all(k.mode == "aot" for k in rep.kernels)
    assert any(k.kind == "edge" or k.stages for k in rep.kernels)
    assert all((k.flops or 0) > 0 for k in rep.kernels)
    text = rep.describe()
    assert "accelerator [local" in text and "live peak" in text
    assert rep.total_flops_per_launch_set > 0
    # pass report rides along (the artifact documents its own pipeline)
    assert any("pass " in line for line in text.splitlines())


def test_distributed_lowering_is_lazy_but_reported(graph):
    prog = repro.compile(sources.PAGERANK)
    acc = prog.lower(Target(kind="distributed"), GraphShape.of(graph))
    assert acc.library is None
    assert all(k.mode == "lazy" for k in acc.report().kernels)
    ref = prog.bind(graph, backend="distributed").run(iters=4)
    got = acc.bind(graph).run(iters=4)
    _assert_results_equal(ref, got)


# ---------------------------------------------------------------------------
# save / load round-trip (the acceptance matrix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", sorted(ALGORITHMS))
@pytest.mark.parametrize("backend", ["local", "distributed"])
@pytest.mark.parametrize("passes", ["default", "none"])
def test_save_load_roundtrip_matrix(graph, tmp_path, algo, backend, passes):
    src, params, prop = ALGORITHMS[algo]
    opts = CompileOptions(passes=passes)
    prog = repro.compile(src, opts)
    target = Target.from_options(opts, kind=backend)
    acc = prog.lower(target, GraphShape.of(graph))
    ref = prog.bind(graph, backend=backend).run(**params)
    path = acc.save(str(tmp_path / f"{algo}-{backend}-{passes}"))
    loaded = repro.load_accelerator(path)
    assert loaded.fingerprint == acc.fingerprint
    got = loaded.bind(graph).run(**params)
    _assert_results_equal(ref, got)
    assert prop in got.properties


def test_loaded_artifact_prefers_stored_executables(graph, tmp_path):
    acc = repro.compile(sources.BFS_ECP).lower(graph=graph)
    path = acc.save(str(tmp_path / "bfs"))
    loaded = repro.load_accelerator(path)
    modes = {k.mode for k in loaded.report().kernels}
    # either every executable deserialized (aot-loaded) or the backend
    # cannot serialize and everything transparently re-lowered (aot)
    assert modes <= {"aot-loaded", "aot"}
    _assert_results_equal(acc.bind(graph).run(root=7),
                          loaded.bind(graph).run(root=7))


def test_save_without_executables_relowers(graph, tmp_path):
    acc = repro.compile(sources.WCC).lower(graph=graph)
    path = acc.save(str(tmp_path / "wcc"), include_executables=False)
    loaded = repro.load_accelerator(path)
    assert all(k.mode == "aot" for k in loaded.report().kernels)
    _assert_results_equal(acc.bind(graph).run(), loaded.bind(graph).run())


def test_load_rejects_stale_artifact(graph, tmp_path):
    import json
    import os

    acc = repro.compile(sources.BFS_ECP).lower(graph=graph)
    path = acc.save(str(tmp_path / "bfs"))
    # tamper with the stored source: the recompiled fingerprint must differ
    with open(os.path.join(path, "program.gt")) as f:
        drifted = f.read().replace(
            "func main()", "const drift: int = 1;\nfunc main()", 1
        )
    with open(os.path.join(path, "program.gt"), "w") as f:
        f.write(drifted)
    with pytest.raises(AcceleratorError, match="stale"):
        repro.load_accelerator(path)
    # and a wrong format version fails loudly
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format"] = 999
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(AcceleratorError, match="format"):
        repro.load_accelerator(path)


def test_accelerator_fingerprint_is_content_keyed(graph):
    prog = repro.compile(sources.BFS_ECP)
    s = GraphShape.of(graph)
    f1 = accelerator_fingerprint(prog.fingerprint, Target(), s)
    assert f1 == accelerator_fingerprint(prog.fingerprint, Target(), s)
    assert f1 != accelerator_fingerprint(prog.fingerprint, Target.baseline(), s)
    assert f1 != accelerator_fingerprint(
        prog.fingerprint, Target(), GraphShape(s.n_vertices, s.n_edges + 1, True)
    )


# ---------------------------------------------------------------------------
# engine time split + LRU program cache satellites
# ---------------------------------------------------------------------------


def test_compile_time_split_cold_then_warm(graph):
    from repro.core.program import clear_program_cache

    clear_program_cache()
    sess = repro.compile(sources.PAGERANK).bind(graph)
    cold = sess.run(iters=4)
    assert cold.stats.compile_time_s > 0
    assert cold.stats.wall_time_s >= cold.stats.compile_time_s
    warm = sess.run(iters=4)
    assert warm.stats.compile_time_s == 0.0
    assert warm.stats.run_time_s == warm.stats.wall_time_s > 0


def test_program_cache_is_lru():
    from repro.core.program import (
        clear_program_cache,
        program_cache_size,
        set_program_cache_limit,
    )

    clear_program_cache()
    set_program_cache_limit(2)
    try:
        srcs = [
            sources.BFS_ECP,
            sources.PAGERANK,
            sources.WCC,
        ]
        progs = [repro.compile(s) for s in srcs]
        info = repro.program_cache_info()
        assert info.maxsize == 2 and info.currsize == 2
        assert info.evictions >= 1
        # evicted entries recompile to an equal (but distinct) Program
        again = repro.compile(srcs[0])
        assert again is not progs[0]
        assert again.fingerprint == progs[0].fingerprint
        # cached entries hit
        hits_before = repro.program_cache_info().hits
        assert repro.compile(srcs[0]) is again
        assert repro.program_cache_info().hits > hits_before
    finally:
        set_program_cache_limit(64)
        clear_program_cache()


def test_program_cache_info_counts():
    from repro.core.program import clear_program_cache

    clear_program_cache()
    repro.compile(sources.BFS_ECP)
    misses = repro.program_cache_info().misses
    assert misses >= 1
    repro.compile(sources.BFS_ECP)
    info = repro.program_cache_info()
    assert info.hits >= 1 and info.currsize == 1
