"""End-to-end algorithm tests vs networkx / numpy oracles, across every
back-end optimization configuration (paper Fig. 8 / Fig. 9 axes)."""
import networkx as nx
import numpy as np
import pytest

from repro.core import CompileOptions
from repro.graph import generators
from repro.algorithms import (
    run_bfs,
    run_bfs_hybrid,
    run_cgaw,
    run_kcore,
    run_pagerank,
    run_ppr,
    run_sssp,
    run_wcc,
)

OPTION_SETS = {
    "baseline": CompileOptions.baseline(),
    "burst": CompileOptions.with_only("burst"),
    "cache": CompileOptions.with_only("cache"),
    "shuffle": CompileOptions.with_only("shuffle"),
    "full": CompileOptions.full(),
    "pallas": CompileOptions.full(pallas=True),
}


@pytest.fixture(scope="module")
def graph():
    return generators.power_law(400, 2600, seed=7)


@pytest.fixture(scope="module")
def wgraph():
    return generators.power_law(400, 2600, seed=7, weighted=True)


@pytest.fixture(scope="module")
def nx_graph(graph):
    G = nx.DiGraph()
    G.add_nodes_from(range(graph.n_vertices))
    G.add_edges_from(zip(graph.src.tolist(), graph.dst.tolist()))
    return G


@pytest.mark.parametrize("opts", list(OPTION_SETS), ids=list(OPTION_SETS))
def test_bfs_vs_networkx(graph, nx_graph, opts):
    level, _ = run_bfs(graph, root=0, options=OPTION_SETS[opts])
    dist = nx.single_source_shortest_path_length(nx_graph, 0)
    want = np.full(graph.n_vertices, -1)
    for v, d in dist.items():
        want[v] = d + 1
    np.testing.assert_array_equal(level, want)


def test_bfs_hybrid_matches_ecp(graph):
    l1, _ = run_bfs(graph, 0, CompileOptions.full())
    l2, res = run_bfs_hybrid(graph, 0, CompileOptions.full())
    np.testing.assert_array_equal(l1, l2)
    assert res.stats.host_iterations > 0


def test_bfs_frontier_compaction_traverses_fewer_edges(graph):
    _, res_base = run_bfs(graph, 0, CompileOptions.baseline())
    _, res_full = run_bfs(graph, 0, CompileOptions.full())
    assert res_full.stats.edges_traversed < res_base.stats.edges_traversed
    assert res_full.stats.compacted_launches > 0


@pytest.mark.parametrize("opts", ["baseline", "full", "pallas"])
def test_pagerank_vs_power_iteration(graph, opts):
    rank, _ = run_pagerank(graph, iters=30, options=OPTION_SETS[opts])
    v = graph.n_vertices
    deg = graph.out_degree.astype(np.float64)
    r = np.full(v, 1.0 / v)
    for _ in range(30):
        contrib = np.zeros(v)
        ok = deg[graph.src] > 0
        np.add.at(contrib, graph.dst, np.where(ok, r[graph.src] / np.maximum(deg[graph.src], 1), 0.0))
        r = 0.15 / v + 0.85 * contrib
    np.testing.assert_allclose(rank, r, rtol=3e-4, atol=1e-7)


@pytest.mark.parametrize("opts", ["baseline", "shuffle", "full"])
def test_sssp_vs_dijkstra(wgraph, opts):
    sp, _ = run_sssp(wgraph, root=0, options=OPTION_SETS[opts])
    G = nx.DiGraph()
    G.add_nodes_from(range(wgraph.n_vertices))
    for s, d, w in zip(wgraph.src.tolist(), wgraph.dst.tolist(), wgraph.weights.tolist()):
        if not G.has_edge(s, d) or G[s][d]["weight"] > w:
            G.add_edge(s, d, weight=w)
    dist = nx.single_source_dijkstra_path_length(G, 0)
    INF = 1073741823
    want = np.full(wgraph.n_vertices, INF, np.int64)
    for vv, dd in dist.items():
        want[vv] = int(dd)
    np.testing.assert_array_equal(sp, want)


def test_ppr_properties(graph):
    ppr, res = run_ppr(graph, source=0, options=CompileOptions.full())
    assert ppr.min() >= 0
    assert 0 < ppr.sum() <= 1.0 + 1e-3
    assert ppr[0] >= ppr.mean()  # personalization mass concentrates at source
    assert res.stats.host_iterations < 100  # converged before the cap


def test_cgaw_softmax_normalization(wgraph):
    w, _ = run_cgaw(wgraph, options=CompileOptions.full())
    sums = np.zeros(wgraph.n_vertices)
    np.add.at(sums, wgraph.dst, w)
    has_in = np.bincount(wgraph.dst, minlength=wgraph.n_vertices) > 0
    np.testing.assert_allclose(sums[has_in], 1.0, rtol=1e-4)
    assert (w > 0).all()


def test_cgaw_option_equivalence(wgraph):
    w0, _ = run_cgaw(wgraph, options=CompileOptions.baseline())
    w1, _ = run_cgaw(wgraph, options=CompileOptions.full())
    np.testing.assert_allclose(w0, w1, rtol=1e-4)


def test_wcc_vs_networkx(graph, nx_graph):
    comp, _ = run_wcc(graph, options=CompileOptions.full())
    for cc in nx.weakly_connected_components(nx_graph):
        ids = comp[list(cc)]
        assert len(set(ids.tolist())) == 1
    n_ours = len(set(comp.tolist()))
    assert n_ours == nx.number_weakly_connected_components(nx_graph)


def test_kcore_invariant(graph):
    alive, _ = run_kcore(graph, k=3, options=CompileOptions.full())
    # every surviving vertex has >= k surviving (in+out) neighbors
    keep = alive.astype(bool)
    deg = np.zeros(graph.n_vertices, np.int64)
    both = keep[graph.src] & keep[graph.dst]
    np.add.at(deg, graph.src[both], 1)
    np.add.at(deg, graph.dst[both], 1)
    assert (deg[keep] >= 3).all()


def test_bfs_on_table_ii_dataset():
    from repro.graph.datasets import make_dataset

    g = make_dataset("R19", scale=0.002, seed=1)
    level, res = run_bfs(g, root=0, options=CompileOptions.full())
    assert (level >= -1).all()
    assert res.stats.host_iterations >= 1
