"""End-to-end tracing tests: span trees, exporters, profile persistence.

The tracer is process-global, so every test runs under the ``tracer``
fixture, which guarantees a fresh enabled tracer on entry and a swap
back to the null tracer on exit (pytest-xdist shards by test, and within
one worker tests are sequential, so no cross-test bleed).
"""
import json

import numpy as np
import pytest

import repro
from repro import telemetry
from repro.algorithms import sources
from repro.core.program import clear_program_cache
from repro.graph import generators


@pytest.fixture
def tracer():
    tr = telemetry.enable()
    tr.reset()
    yield tr
    telemetry.disable()


def _tree_names(tr, root_span):
    """All span names reachable from root_span (exclusive) via parent links."""
    by_parent = {}
    for s in tr.spans():
        by_parent.setdefault(s.parent_id, []).append(s)
    names, stack = [], [root_span.span_id]
    while stack:
        sid = stack.pop()
        for child in by_parent.get(sid, []):
            names.append(child.name)
            stack.append(child.span_id)
    return names


# --------------------------------------------------------------------------
# golden span trees
# --------------------------------------------------------------------------


def test_golden_span_tree_local_bfs(tracer):
    clear_program_cache()
    g = generators.power_law(300, 2400, seed=2)
    program = repro.compile(sources.BFS_ECP)
    acc = program.lower(repro.Target(), shape=repro.GraphShape.of(g))
    result = acc.bind(g).run(root=3)

    by_name = {}
    for s in tracer.spans():
        by_name.setdefault(s.name, []).append(s)
    assert len(by_name["compile"]) == 1
    assert len(by_name["lower"]) == 1
    assert len(by_name["bind"]) == 1
    assert len(by_name["run"]) == 1

    # every kernel launch counted by EngineStats appears as a launch span
    launch_spans = [
        s for s in tracer.spans() if s.name.startswith("launch:")
    ]
    assert len(launch_spans) == result.stats.total_launches

    # one connected tree: all launch spans descend from the run span
    run_span = by_name["run"][0]
    names = _tree_names(tracer, run_span)
    assert sum(n.startswith("launch:") for n in names) == len(launch_spans)
    assert all(s.trace_id == run_span.trace_id for s in launch_spans)

    # spans carry their typed attributes
    assert by_name["compile"][0].attrs["fingerprint"]
    assert by_name["lower"][0].attrs["target"] == "local"
    assert by_name["bind"][0].attrs["n_vertices"] == g.n_vertices
    assert run_span.attrs["launches"] == result.stats.total_launches
    modes = {s.attrs.get("mode") for s in launch_spans}
    assert modes <= {"full", "compacted"}

    # the per-run summary rides on the result and matches the tree
    assert result.trace is not None
    trace_launches = sum(
        agg["count"] for name, agg in result.trace["spans"].items()
        if name.startswith("launch:")
    )
    assert trace_launches == result.stats.total_launches


def test_golden_span_tree_distributed_bfs(subproc):
    out = subproc(
        """
import numpy as np
import repro
from repro import telemetry
from repro.algorithms import sources
from repro.graph import generators

tr = telemetry.enable()
g = generators.power_law(300, 2400, seed=2)
program = repro.compile(sources.BFS_ECP)
result = program.bind(g, backend="distributed").run(root=3)

spans = tr.spans()
by_name = {}
for s in spans:
    by_name.setdefault(s.name, []).append(s)
launch_spans = [s for s in spans if s.name.startswith("launch:")]
assert len(launch_spans) == result.stats.total_launches, (
    len(launch_spans), result.stats.total_launches)
assert len(by_name["run"]) == 1
run_span = by_name["run"][0]
assert run_span.attrs["engine"] == "DistEngine"
assert all(s.trace_id == run_span.trace_id for s in launch_spans)
supersteps = by_name.get("superstep", [])
assert result.stats.dist_supersteps > 0
assert len(supersteps) == result.stats.dist_supersteps, (
    len(supersteps), result.stats.dist_supersteps)
assert all(s.attrs["devices"] >= 1 for s in supersteps)
assert all(s.attrs["shuffle_elements"] > 0 for s in supersteps)
dist_modes = {s.attrs.get("mode") for s in launch_spans}
assert "dist" in dist_modes, dist_modes
telemetry.disable()
print("dist trace ok")
""",
        devices=4,
    )
    assert "dist trace ok" in out


# --------------------------------------------------------------------------
# enable/disable round trip
# --------------------------------------------------------------------------


def test_disable_retains_zero_spans():
    tr = telemetry.enable()
    tr.reset()
    g = generators.power_law(200, 1200, seed=0)
    repro.compile(sources.BFS_ECP).bind(g).run(root=0)
    assert tr.spans()

    telemetry.disable()
    assert telemetry.get().spans() == []
    assert not telemetry.enabled()
    # the old tracer object was drained too (no hidden retention)
    assert tr.spans() == []

    # instrumented paths still run (as no-ops) while disabled
    result = repro.compile(sources.BFS_ECP).bind(g).run(root=1)
    assert telemetry.get().spans() == []
    assert result.trace is None

    # re-enable starts clean
    tr2 = telemetry.enable()
    try:
        assert tr2.spans() == []
        r2 = repro.compile(sources.BFS_ECP).bind(g).run(root=2)
        assert r2.trace is not None
        assert any(s.name == "run" for s in tr2.spans())
    finally:
        telemetry.disable()


def test_null_tracer_api_is_complete(tmp_path):
    telemetry.disable()
    tr = telemetry.get()
    assert not tr.enabled
    with tr.span("anything", attr=1) as sp:
        sp.set(more=2)
    assert tr.current() is None
    assert tr.spans() == []
    assert tr.summarize()["span_count"] == 0
    # exporters still produce valid (empty) documents
    out = tmp_path / "empty.json"
    assert tr.export_chrome(str(out)) == 0
    doc = json.loads(out.read_text())
    assert doc["traceEvents"] == []
    assert tr.prometheus_text() == ""  # empty exposition is valid


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------


def test_chrome_export_valid_trace_event_json(tracer, tmp_path):
    g = generators.power_law(200, 1200, seed=1)
    repro.compile(sources.BFS_ECP).bind(g).run(root=0)
    path = tmp_path / "trace.json"
    n = tracer.export_chrome(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    complete = [e for e in events if e["ph"] == "X"]
    assert len(complete) == n == len(tracer.spans())
    for e in complete:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert "span_id" in e["args"] and "trace_id" in e["args"]
    # thread metadata events make Perfetto lanes readable
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in events)


def test_prometheus_exposition(tracer):
    g = generators.power_law(200, 1200, seed=1)
    repro.compile(sources.BFS_ECP).bind(g).run(root=0)
    text = tracer.prometheus_text()
    assert 'repro_span_count{span="run"} 1' in text
    assert 'repro_span_duration_seconds_sum{span="run"}' in text
    assert 'quantile="0.99"' in text


# --------------------------------------------------------------------------
# serving integration
# --------------------------------------------------------------------------


def test_service_request_span_trees_and_stats(tracer):
    g = generators.power_law(200, 1400, seed=5)
    with repro.serve(False, workers=2, max_batch=4) as svc:
        futs = [svc.submit("bfs", g, root=r) for r in range(3)]
        for f in futs:
            f.result()
        svc.scheduler.drain(timeout=30)
        stats = svc.stats()

    roots = [s for s in tracer.spans() if s.name == "schedule"]
    assert len(roots) == 3
    for root in roots:
        names = _tree_names(tracer, root)
        assert "queue_wait" in names
        assert "execute" in names
    # the text exposition is merged into the stats snapshot
    assert "repro_span_count" in stats["telemetry"]
    assert 'span="execute"' in stats["telemetry"]


# --------------------------------------------------------------------------
# profile persistence
# --------------------------------------------------------------------------


def test_profile_persists_with_artifact(tracer, tmp_path):
    clear_program_cache()
    g = generators.power_law(300, 2400, seed=3)
    program = repro.compile(sources.BFS_ECP)
    acc = program.lower(repro.Target(), shape=repro.GraphShape.of(g))
    session = acc.bind(g)
    session.run(root=1)
    session.run(root=2)

    prof = acc.report().profile
    assert prof["runs"] == 2
    assert any(name.startswith("launch:") for name in prof["spans"])
    for agg in prof["spans"].values():
        assert agg["count"] > 0 and agg["total_s"] >= 0

    acc.save(str(tmp_path / "bfs"))
    loaded = repro.load_accelerator(str(tmp_path / "bfs"))
    inherited = loaded.report().profile
    assert inherited["runs"] == 2
    assert inherited["spans"].keys() == prof["spans"].keys()
    # warm runs keep accumulating on top of the inherited baseline
    loaded.bind(g).run(root=3)
    assert loaded.report().profile["runs"] == 3
    assert "traced run(s)" in loaded.report().describe()


def test_result_trace_none_when_untraced():
    telemetry.disable()
    g = generators.power_law(200, 1200, seed=0)
    result = repro.compile(sources.BFS_ECP).bind(g).run(root=0)
    assert result.trace is None


def test_batched_runs_share_one_trace_summary(tracer):
    g = generators.power_law(300, 2400, seed=4)
    batch = repro.compile(sources.BFS_ECP).bind_batch(g)
    roots = np.arange(4)
    results = batch.run_many([{"root": int(r)} for r in roots])
    traces = {id(r.trace) for r in results}
    assert len(traces) == 1
    trace = results[0].trace
    assert trace["span_count"] >= 1
    run_spans = [s for s in tracer.spans() if s.name == "run"]
    assert any(s.attrs.get("batch_size", 0) >= 1 for s in run_spans)


# --------------------------------------------------------------------------
# head-based trace sampling (always-on production tracing)
# --------------------------------------------------------------------------


def test_sample_zero_drops_whole_traces():
    tr = telemetry.tracer.Tracer(sample=0.0)
    with tr.span("root") as root:
        # the whole trace is dropped: descendants are no-ops, the context
        # never leaks a half-recorded tree
        assert root.context() is None
        assert tr.current() is None
        with tr.span("child") as child:
            assert child is telemetry.NULL_SPAN
            with tr.span("grandchild"):
                pass
    assert tr.spans() == []
    assert tr.sampled_out == 1  # one dropped *trace*, not three spans
    assert tr.summarize()["span_count"] == 0


def test_sample_one_keeps_everything():
    tr = telemetry.tracer.Tracer(sample=1.0)
    for _ in range(20):
        with tr.span("root"):
            with tr.span("child"):
                pass
    assert len(tr.spans()) == 40
    assert tr.sampled_out == 0


def test_sampling_is_per_root_and_seed_deterministic():
    def kept_roots(seed):
        tr = telemetry.tracer.Tracer(sample=0.5, seed=seed)
        kept = []
        for i in range(200):
            with tr.span("root", i=i):
                with tr.span("child"):
                    pass
        kept = sorted(s.attrs["i"] for s in tr.spans() if s.name == "root")
        # every kept root kept its child too; every dropped root dropped it
        n_roots = len(kept)
        assert len(tr.spans()) == 2 * n_roots
        assert tr.sampled_out == 200 - n_roots
        return kept

    a, b = kept_roots(seed=7), kept_roots(seed=7)
    assert a == b
    assert 0 < len(a) < 200  # actually sampling, not all-or-nothing
    assert kept_roots(seed=8) != a


def test_explicit_parent_bypasses_sampling():
    # cross-thread handoff: a span with an explicit parent token belongs
    # to an already-kept trace — it must never be re-sampled away
    tr = telemetry.tracer.Tracer(sample=0.0)
    ctx = telemetry.tracer.SpanContext(trace_id=42, span_id=42)
    with tr.span("handed-off", parent=ctx) as sp:
        assert sp is not telemetry.NULL_SPAN
    assert [s.name for s in tr.spans()] == ["handed-off"]
    assert tr.spans()[0].trace_id == 42


def test_record_span_respects_sampling():
    tr = telemetry.tracer.Tracer(sample=0.0)
    sp = tr.record_span("queue_wait", 0.0, 1.0)
    assert sp is not None  # no-op stand-in, never an AttributeError
    assert tr.spans() == []
    assert tr.sampled_out == 1


def test_reset_zeroes_sampled_out_counter():
    tr = telemetry.tracer.Tracer(sample=0.0)
    with tr.span("root"):
        pass
    assert tr.sampled_out == 1
    tr.reset()
    assert tr.sampled_out == 0


def test_enable_sample_validates_and_updates_in_place():
    tr = telemetry.enable(sample=0.25, seed=3)
    try:
        assert tr.sample == 0.25
        # re-enable with an explicit rate retunes the active tracer
        same = telemetry.enable(sample=1.0)
        assert same is tr
        assert tr.sample == 1.0
        # without an explicit rate, enable() leaves the rate alone
        telemetry.enable()
        assert tr.sample == 1.0
        with pytest.raises(ValueError):
            telemetry.enable(sample=1.5)
        with pytest.raises(ValueError):
            telemetry.tracer.Tracer(sample=-0.1)
    finally:
        telemetry.disable()


def test_sampled_trace_still_counts_engine_runs(tracer):
    # a sampled-out run must still *execute* normally — sampling drops
    # telemetry, never work. sample=0.0 on the active tracer, then run.
    telemetry.enable(sample=0.0)
    g = generators.chain(64)
    acc = repro.compile(sources.BFS_ECP).lower(graph=g)
    session = acc.bind(g)
    try:
        res = session.run(root=0)
    finally:
        session.close()
    assert (np.asarray(res.properties["old_level"]) >= 0).sum() == 64
    assert res.trace is None  # dropped trace -> no per-run summary
    assert tracer.sampled_out >= 1
    assert tracer.spans() == []
