"""Checkpoint manager: atomicity, integrity, resume, elastic re-shard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.normal(size=(8, 4)).astype(np.float32)),
                   "b": jnp.asarray(r.normal(size=(4,)).astype(np.float32))},
        "opt": {"m": {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))},
                "step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(10, t)
    step, t2 = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, _tree())
    mgr.wait()
    assert mgr.available_steps() == [5]


def test_keep_policy_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.available_steps() == [3, 4]


def test_corruption_falls_back_to_previous(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(seed=1))
    mgr.save(2, _tree(seed=2))
    # corrupt the newest
    shard = tmp_path / "step_00000002" / "shard_0.npz"
    shard.write_bytes(b"garbage")
    step, t2 = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 1  # silently skipped the damaged checkpoint
    want = _tree(seed=1)
    np.testing.assert_array_equal(
        np.asarray(t2["params"]["w"]), np.asarray(want["params"]["w"])
    )


def test_no_partial_checkpoint_visible(tmp_path):
    """A crash mid-write leaves only a .tmp dir, which restore ignores."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    fake = tmp_path / "step_00000099.tmp"
    fake.mkdir()
    (fake / "shard_0.npz").write_bytes(b"partial")
    assert mgr.available_steps() == [1]


def test_elastic_restore_changes_sharding(tmp_path, subproc):
    """Save on 1 device, restore re-sharded onto a 4-device mesh."""
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    mgr.save(3, t)
    out = subproc(
        f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
mesh = jax.make_mesh((4,), ("data",))
mgr = CheckpointManager({str(tmp_path)!r})
like = {{"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}}
sh = {{"w": NamedSharding(mesh, P("data", None))}}
t = mgr.restore(3, like, sh)
assert len(t["w"].sharding.device_set) == 4, t["w"].sharding
np.testing.assert_array_equal(np.asarray(t["w"]).ravel(), np.arange(32, dtype=np.float32))
print("elastic ok")
""",
        devices=4,
    )
    assert "elastic ok" in out


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises((ValueError, KeyError)):
        mgr.restore(1, {"w": jnp.zeros((5,))})
