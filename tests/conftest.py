import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_subprocess(code: str, devices: int = 8, timeout: int = 420) -> str:
    """Run a snippet in a fresh interpreter with N host devices.

    Multi-device tests need the device count set before jax initializes,
    which the main pytest process has already done — hence subprocesses.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(SRC)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={out.returncode})\nstdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
        )
    return out.stdout


@pytest.fixture
def subproc():
    return run_subprocess
